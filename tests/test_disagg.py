"""Disaggregated prefill/decode serving (ROADMAP item 2).

The acceptance properties pinned here:

* cache payloads pack/unpack BIT-exactly through the per-dtype channel
  buffers, for every cache family (KV, SSM state, hybrid);
* a request migrated prefill GMI -> CacheChannel -> decode GMI produces
  EXACTLY the tokens the aggregated oracle path produces — including
  when the Table-2 cost model (not a forced override) chose migration;
* the MigrationPlanner's crossover follows the cost model: short prompts
  stay local, long prompts migrate, measurements sharpen the estimate;
* ONE controller instance arbitrates decode GMIs AND prefill GMIs:
  ``Decision.prefill_gpus`` grows under sustained prefill backlog and
  shrinks when the specialists idle, and the front's ``apply_decision``
  resizes the prefill set from it;
* the double-replan hazard is closed: a decision captured before an
  ``AsyncRunner`` re-plan (stale ``seq``) is refused with the
  controller's committed split reconciled, and any decision object
  applies AT MOST once per epoch regardless of how many paths see it.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.controller import (ControllerConfig, Decision,
                                   OnlineGMIController)
from repro.core.cost_model import (local_prefill_time, migration_beats_local,
                                   migration_gain, migration_time)
from repro.kernels.channel_pack import (cache_payload_bytes,
                                        pack_cache_payload,
                                        unpack_cache_payload)
from repro.models import transformer as T
from repro.serve import (DisaggFront, MigrationPlanner, PrefillEngine,
                         Request, RequestRouter, ServeEngine)
from repro.serve.telemetry import ServingLoad

V = 64
CASES = {
    "attention": ModelConfig(name="d", num_layers=2, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, vocab_size=V),
    "ssm": ModelConfig(name="x", d_model=64, num_heads=4, num_kv_heads=4,
                       d_ff=0, vocab_size=V,
                       block_pattern=("mlstm",) * 3 + ("slstm",),
                       num_super=2),
    "hybrid": ModelConfig(name="z", d_model=64, num_heads=4, num_kv_heads=4,
                          d_ff=128, vocab_size=V, ssm_state_dim=16,
                          block_pattern=("mamba2",) * 2 + ("attn_shared",),
                          num_super=2),
}

_PARAMS = {}


def params_of(case: str):
    if case not in _PARAMS:
        _PARAMS[case] = T.init_model(jax.random.key(3), CASES[case])
    return _PARAMS[case]


def make_front(case="attention", *, decode=2, prefill=1, planner=None,
               max_slots=2, max_seq=40) -> DisaggFront:
    cfg, params = CASES[case], params_of(case)

    def efac(i, slots=max_slots):
        return ServeEngine(cfg, params, max_slots=slots, max_seq=max_seq,
                           name=f"d{i}")

    def pfac(i):
        return PrefillEngine(cfg, params, max_seq=max_seq, name=f"p{i}")

    router = RequestRouter(engine_factory=efac, num_engines=decode)
    return DisaggFront(router, [pfac(i) for i in range(prefill)],
                       planner=planner or MigrationPlanner(),
                       prefill_factory=pfac)


def force_migrate() -> MigrationPlanner:
    # infinite-bandwidth channel against a glacial local prefill: every
    # prompt migrates, deterministically
    return MigrationPlanner(bandwidth=1e15, latency_s=0.0,
                            prefill_tok_s=1e-6)


def reqs_mixed(n=4, seed=11, budgets=(5, 8, 3, 6), **kw):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, V, int(rng.integers(3, 10))),
                    max_new_tokens=budgets[i % len(budgets)], **kw)
            for i in range(n)]


def load(*, backlog=0, occ=0.5, pf_backlog=0, migrations=0,
         slots=4) -> ServingLoad:
    return ServingLoad(dt=1.0, tokens=100, requests=5,
                       queue_depth_mean=float(backlog),
                       queue_depth_max=backlog, occupancy_mean=occ,
                       backlog=backlog, p50_s=0.05, p95_s=0.1, slots=slots,
                       prefill_backlog=pf_backlog, migrations=migrations)


# ------------------------------------------------------------ cache pack --
def test_cache_payload_pack_roundtrip_bit_exact():
    tree = {"kv": jnp.linspace(-3.0, 7.0, 24,
                               dtype=jnp.float32).reshape(2, 3, 4),
            "pos": jnp.arange(6, dtype=jnp.int32).reshape(1, 6),
            "state": jnp.asarray(np.random.default_rng(0)
                                 .normal(size=(4, 5))).astype(jnp.bfloat16)}
    bufs, meta = pack_cache_payload(tree)
    # coarse-grained: one contiguous buffer per dtype, not per leaf
    assert len(bufs) == 3 and all(b.ndim == 1 for b in bufs)
    assert cache_payload_bytes(bufs) > 0
    out = unpack_cache_payload(bufs, meta)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ cost model --
def test_migration_cost_terms_and_crossover():
    # Table-2 units: transfer latency+bytes/bw vs prompt/prefill-rate
    assert migration_time(1e6, 1e9, latency_s=1e-3) \
        == pytest.approx(1e-3 + 1e-3)
    assert local_prefill_time(100, 1e3) == pytest.approx(0.1)
    # gain is monotone in prompt length at fixed payload size
    g = [migration_gain(1e6, t, 1e9, 1e3) for t in (1, 10, 100)]
    assert g[0] < g[1] < g[2]
    # the 1.05x hysteresis: a marginal win does not migrate
    assert not migration_beats_local(1e6, 1, 1e9, 1e3)
    assert migration_beats_local(1e6, 100, 1e9, 1e3)


def test_planner_crossover_short_local_long_migrates():
    pl = MigrationPlanner(bandwidth=1e9, latency_s=0.0, prefill_tok_s=1e3)
    # 1 MB payload -> 1 ms transfer; local stall = tokens ms
    assert not pl.should_migrate(1e6, 1)       # 1 ms local: no gain
    assert pl.should_migrate(1e6, 100)         # 100 ms local: migrate
    assert pl.migrated == 1 and pl.kept_local == 1
    # measured transfers sharpen the bandwidth estimate (EMA seed)
    pl.observe_transfer(1.0, int(2e9))
    assert pl.bandwidth == pytest.approx(2e9)


# --------------------------------------------------------- token identity --
@pytest.mark.parametrize("case", list(CASES))
def test_migrated_decode_token_identical_to_oracle(case):
    """The acceptance property: prefill on a specialist GMI, cache packed
    over the channel, spliced into a decode GMI mid-batch — EXACTLY the
    oracle's tokens, for KV, SSM, and hybrid cache families."""
    front = make_front(case, planner=force_migrate())
    reqs = reqs_mixed(4, seed=11, budgets=(5, 8, 3, 6))
    reqs.append(Request(tokens=np.arange(6), max_new_tokens=6,
                        temperature=0.8, seed=42))     # sampled request
    oracle = {r.rid: front.router.engines[0].oracle_generate(r)
              for r in reqs}
    for r in reqs:
        front.submit(r)
    # everything migrated: the decode router saw no raw submissions
    assert front.router.queue_len == 0
    assert sum(e.load for e in front.prefill_engines) == len(reqs)
    done = front.drain()
    assert len(done) == len(reqs)
    assert front.planner.migrated == len(reqs)
    for c in done:
        assert c.status == "ok"
        assert c.tokens == oracle[c.rid], \
            f"{case}: migrated decode diverged from the oracle"
    ep = front.take_epoch()
    assert ep.migrations == len(reqs) and ep.prefill_s > 0.0


def test_cost_model_chosen_migration_token_identical():
    """Mixed traffic under a REAL planner decision (no force): the
    crossover splits short-local from long-migrate — priced PER PAGE
    (``DisaggFront.request_bytes``), so a 9-token prompt (2 pages of 8)
    costs twice the wire time of a 3-token one — and both paths stay
    token-identical to the oracle."""
    front = make_front("attention", decode=2, prefill=1)
    eng = front.router.engines[0]
    # per-page wire time tau_p: with local prefill at 1e3 tok/s and
    # min_gain=1.05, a p-page prompt of n tokens migrates iff
    # n >= 1.05e3 * p * tau_p.  tau_p=4.1ms puts 3- and 4-token prompts
    # (1 page, too short) local, 8 (1 page) and 9 (2 pages) migrating.
    tau_p = 4.1e-3
    front.planner.static_bandwidth = \
        front.payload_bytes / (eng.pages_per_slot * tau_p)
    front.planner.latency_s = 0.0
    front.planner._prefill_tok_s = 1e3
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, V, n), max_new_tokens=4)
            for n in (3, 4, 8, 9, 3, 9)]
    oracle = {r.rid: front.router.engines[0].oracle_generate(r)
              for r in reqs}
    done = front.serve(reqs)
    assert front.planner.migrated == 3 and front.planner.kept_local == 3
    assert len(done) == len(reqs)
    for c in done:
        assert c.tokens == oracle[c.rid]


def test_prefill_death_without_survivors_falls_back_to_local():
    """No factory, no surviving specialist: the dead GMI's requests fall
    back to the decode side's local-prefill path and still complete."""
    cfg, params = CASES["attention"], params_of("attention")
    router = RequestRouter([ServeEngine(cfg, params, max_slots=2,
                                        max_seq=40, name=f"d{i}")
                            for i in range(2)])
    pf = PrefillEngine(cfg, params, max_seq=40)
    front = DisaggFront(router, [pf], planner=force_migrate())
    reqs = reqs_mixed(3, seed=21, budgets=(4, 5, 3))
    oracle = {r.rid: router.engines[0].oracle_generate(r) for r in reqs}
    for r in reqs:
        front.submit(r)
    assert front.fail_prefill_engine(pf) == len(reqs)
    assert not front.prefill_engines
    done = front.drain()
    assert {c.rid for c in done} == {r.rid for r in reqs}
    for c in done:
        assert c.status == "ok" and c.tokens == oracle[c.rid]


# ---------------------------------------------------- controller arbitration --
def test_controller_prefill_arbitration_grows_and_shrinks():
    ctl = OnlineGMIController(num_gpu=6, serving_gpus=4, gmi_per_gpu=1,
                              num_env=8, cfg=ControllerConfig(epoch_rounds=1))
    ctl.prefill_gpus = 1
    d = ctl.observe_serving(load(pf_backlog=3, migrations=2))
    assert d is not None and d.prefill_gpus == 2 and d.layout_changed
    assert ctl.prefill_gpus == 2 and "prefill backlog" in d.reason
    # an epoch with zero prefill work anywhere gives the GMI back
    d2 = ctl.observe_serving(load())
    assert d2 is not None and d2.prefill_gpus == 1
    assert ctl.prefill_gpus == 1 and "prefill idle" in d2.reason


def test_aggregated_telemetry_never_triggers_prefill_arbitration():
    ctl = OnlineGMIController(num_gpu=6, serving_gpus=4, gmi_per_gpu=1,
                              num_env=8, cfg=ControllerConfig(epoch_rounds=1))
    assert ctl.observe_serving(load()) is None
    assert ctl.prefill_gpus == 0


def test_front_apply_decision_scales_prefill_set():
    front = make_front("attention", prefill=1)
    ctl = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=1,
                              num_env=8)
    ctl.prefill_gpus = 2
    d = Decision(num_env=8, gmi_per_gpu=1, serving_gpus=2,
                 reason="grow prefill", prefill_gpus=2, seq=0)
    assert front.apply_decision(d, controller=ctl) is True
    assert len(front.prefill_engines) == 2
    # prefill_gpus == 0 means pure local prefill; one engine stays warm
    d0 = Decision(num_env=8, gmi_per_gpu=1, serving_gpus=2,
                  reason="shrink prefill", prefill_gpus=0, seq=0)
    front.apply_decision(d0, controller=ctl)
    assert len(front.prefill_engines) == 1


# --------------------------------------------------- double-replan hazard --
def test_stale_decision_refused_and_split_reconciled():
    """Regression: a serving decision captured BEFORE an AsyncRunner
    re-plan drained must not apply afterwards — the re-plan bumps
    ``plan_seq``, the apply hook refuses the stale seq and reconciles the
    controller's committed split back to the real fleet."""
    ctl = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=1,
                              num_env=8, cfg=ControllerConfig(epoch_rounds=1))
    front = make_front("attention", decode=2)
    d = ctl.observe_serving(load(backlog=3, occ=1.0))
    assert d is not None and d.serving_gpus == 3 and d.seq == 0
    assert ctl.serving_gpus == 3                 # committed at emission
    ctl.plan_seq += 1                            # a re-plan intervened
    assert front.apply_decision(d, controller=ctl) is False
    assert front.router.stale_decisions == 1
    assert front.router.num_engines == 2         # nothing moved
    assert ctl.serving_gpus == 2                 # reconciled to achieved


def test_decision_applies_at_most_once_per_epoch():
    """Regression: the runner-driven apply path and a direct
    ``maybe_replan`` caller can never BOTH act on one epoch's decision —
    the second application of the same object is a no-op."""
    ctl = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=1,
                              num_env=8, cfg=ControllerConfig(epoch_rounds=1))
    router = RequestRouter(
        engine_factory=lambda i, slots=2: ServeEngine(
            CASES["attention"], params_of("attention"), max_slots=slots,
            max_seq=40, name=f"e{i}"),
        num_engines=2)
    d = ctl.observe_serving(load(backlog=3, occ=1.0))
    assert d is not None and d.layout_changed
    assert router.apply_decision(d, controller=ctl) is True
    assert router.num_engines == 3
    assert router.apply_decision(d, controller=ctl) is False
    assert router.num_engines == 3 and router.stale_decisions == 0


# -------------------------------------------------- single-arbiter runner --
def test_one_controller_arbitrates_rollout_and_serving():
    """The control-plane collapse: ONE OnlineGMIController instance,
    living in the AsyncRunner round loop, folds rollout telemetry AND the
    serving front's epochs; ``replan`` bumps the staleness fence."""
    from repro.core.placement import plan_async
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    layout = plan_async(3, 2, 2, devices=list(range(6)), devices_per_gpu=2)
    front = make_front("attention", decode=2, prefill=1,
                       planner=force_migrate())
    runner = make_async_runner(
        make_env("Ant"), layout, online_controller=True, router=front,
        num_envs=4, num_steps=2,
        controller_cfg=ControllerConfig(epoch_rounds=2, probe=False))
    ctl = runner.controller
    assert runner.router is front and ctl is not None
    for i in range(2):
        for r in reqs_mixed(2, seed=30 + i, budgets=(3, 4)):
            front.submit(r)
        front.drain()
        runner.round()
    runner.finish()
    # the SAME instance measured both halves
    assert ctl._table and ctl._serving_table
    key = next(iter(ctl._serving_table))
    assert key[0] == ctl.gmi_per_gpu
    # replan bumps the staleness fence the serving guard keys on
    seq0 = ctl.plan_seq
    runner.replan(Decision(num_env=4, gmi_per_gpu=2, serving_gpus=2,
                           reason="fence test"))
    assert ctl.plan_seq == seq0 + 1


# ------------------------------------------------------------- paged wires --
def test_paged_migration_ships_partial_payload():
    """Migration prices and ships WHOLE PAGES of the prompt, not the full
    per-slot cache: a 4-page prompt's payload is measurably bigger on the
    wire than a 1-page prompt's, and the measured per-page rate feeds
    request_bytes."""
    front = make_front("attention", decode=1, prefill=1,
                       planner=force_migrate())
    rng = np.random.default_rng(3)
    short = Request(tokens=rng.integers(0, V, 4), max_new_tokens=3)
    long = Request(tokens=rng.integers(0, V, 28), max_new_tokens=3)
    oracle = {r.rid: front.router.engines[0].oracle_generate(r)
              for r in (short, long)}
    done = front.serve([short])
    b_short = front._payload_bytes            # wire bytes of the last send
    done += front.serve([long])
    b_long = front._payload_bytes
    assert len(done) == 2
    for c in done:
        assert c.tokens == oracle[c.rid]
    # ceil(4/8)=1 page vs ceil(28/8)=4 pages: the wire sees the difference
    assert b_long > b_short > 0
    assert front._page_bytes is not None and front._page_bytes > 0
    # ...and the planner's estimate now scales with the prompt
    assert front.request_bytes(28) > front.request_bytes(4)


def test_shared_prefix_skips_pages_across_migration():
    """Second migrated request sharing a 2-block prompt head: the front
    strips the head pages the decode engine's prefix index already holds
    (prefix_pages_saved), and the spliced decode stays token-identical."""
    front = make_front("attention", decode=1, prefill=1,
                       planner=force_migrate(), max_seq=48)
    eng = front.router.engines[0]
    rng = np.random.default_rng(13)
    head = rng.integers(0, V, 16)            # two full 8-token pages
    r1 = Request(tokens=np.concatenate([head, rng.integers(0, V, 3)]),
                 max_new_tokens=4)
    r2 = Request(tokens=np.concatenate([head, rng.integers(0, V, 6)]),
                 max_new_tokens=5)
    oracle = {r.rid: eng.oracle_generate(r) for r in (r1, r2)}
    done = front.serve([r1])
    assert front.prefix_pages_saved == 0     # nothing promoted yet
    assert eng.shared_head_pages(r2.tokens) == 2
    done += front.serve([r2])
    assert front.prefix_pages_saved == 2     # r2's head never hit the wire
    assert len(done) == 2
    for c in done:
        assert c.tokens == oracle[c.rid]
    assert front.planner.migrated == 2


def test_stale_prefix_promise_falls_back_to_local_prefill():
    """A head-stripped payload landing on an engine that does NOT hold the
    promised prefix pages re-queues for a full local prefill — lossless,
    token-identical, counted in ``prefix_fallbacks``."""
    from repro.kernels.channel_pack import truncate_cache_pages
    cfg, params = CASES["attention"], params_of("attention")
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=40, name="d0")
    pf = PrefillEngine(cfg, params, max_seq=40, name="p0")
    req = Request(tokens=np.arange(12) % V, max_new_tokens=5)
    oracle = eng.oracle_generate(req)
    pf.submit(req)
    payload = pf.step()
    assert payload is not None
    # strip the first page on a PROMISE the engine cannot honor (its
    # prefix index has never seen this prompt)
    payload.cache = truncate_cache_pages(payload.cache,
                                         payload.prompt_tokens,
                                         eng.page_size, head_skip=1)
    payload.head_pages = 1
    assert eng.shared_head_pages(req.tokens) == 0
    eng.submit_prefilled(payload)
    done = eng.run_until_idle()
    assert eng.prefix_fallbacks == 1
    assert len(done) == 1 and done[0].tokens == oracle
