import numpy as np
import pytest

from repro.core.cost_model import (WorkloadProfile, best_lgr, lgr_time_har,
                                   lgr_time_mpr, lgr_time_mrr,
                                   serving_speedup_tcg_over_tdg,
                                   training_speedup_tcg_over_tdg)
from repro.core.gmi import GMIManager
from repro.core.placement import (plan_async, plan_tcg_ex_training,
                                  plan_tcg_serving, plan_tdg_serving,
                                  select_reduction_strategy)
from repro.core.selection import ProfilePoint, explore


def test_manager_registration_and_mapping():
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)
        mgr.set_gpu(gid, gpu)
    assert mgr.gmi_to_gpu_mapping("trainer") == [[0, 1], [2, 3]]
    assert mgr.gmis[0].num_devices == 2
    with pytest.raises(ValueError):
        mgr.add_gmi(0)
    # overcommit: a 5th half-GPU instance on gpu 0 must fail
    mgr.add_gmi(9, "trainer", 0.75)
    with pytest.raises(ValueError):
        mgr.set_gpu(9, 0)


def test_algorithm1_cases():
    # paper Algorithm 1, line-by-line behaviours
    assert select_reduction_strategy([[0, 1, 2]]) == "mpr"
    assert select_reduction_strategy([[0], [1]]) == "mrr"
    assert select_reduction_strategy([[0, 1], [2, 3], [4, 5]]) == "mrr"
    assert select_reduction_strategy([[0, 1, 2], [3, 4]]) == "har"
    assert select_reduction_strategy([[0, 1, 2], [3, 4, 5]]) == "har"


def test_layout_templates():
    tcg = plan_tcg_serving(2, 3, devices=list(range(12)), devices_per_gpu=6)
    assert len(tcg.serving_gmis) == 6
    tdg = plan_tdg_serving(2, 2, devices=list(range(20)),
                           devices_per_gpu=10)
    roles = {g.role for g in tdg.manager.gmis.values()}
    assert roles == {"simulator", "agent"}
    ex = plan_tcg_ex_training(2, 2, devices=list(range(8)),
                              devices_per_gpu=4)
    assert ex.reduction_strategy() == "mrr"       # t=2 == g=2
    ex2 = plan_tcg_ex_training(2, 3, devices=list(range(12)),
                               devices_per_gpu=6)
    assert ex2.reduction_strategy() == "har"      # t=3 > g=2
    asy = plan_async(4, 2, 2, devices=list(range(16)), devices_per_gpu=4)
    assert len(asy.serving_gmis) == 4 and len(asy.trainer_gmis) == 4


def test_lgr_cost_model_orderings():
    # Table 2: with NCCL bandwidth >> host bandwidth, HAR beats MPR, and the
    # HAR advantage grows with more instances per GPU
    M, B1, B2 = 1.5e6, 5e9, 200e9
    assert lgr_time_har(4, 4, M, B1, B2) < lgr_time_mpr(4, 4, M, B1, B2)
    assert best_lgr(2, 8, M, B1, B2) in ("har", "mpr")  # mrr infeasible t>g
    # absolute HAR saving exists at every scale (Table 2 with B2 >> B1)
    for g, t in [(2, 2), (4, 4), (8, 4)]:
        assert lgr_time_har(g, t, M, B1, B2) < lgr_time_mpr(g, t, M, B1, B2)
    # and HAR's cross-GPU stage rides the fast interconnect: doubling B2
    # shrinks HAR time but leaves MPR untouched
    assert lgr_time_har(4, 4, M, B1, 2 * B2) < lgr_time_har(4, 4, M, B1, B2)
    assert lgr_time_mpr(4, 4, M, B1, 2 * B2) == lgr_time_mpr(4, 4, M, B1, B2)


def test_paper_speedup_claims():
    s = serving_speedup_tcg_over_tdg()
    t = training_speedup_tcg_over_tdg()
    assert 2.0 < s < 3.2, f"serving speedup {s} out of the paper's ~2.5x band"
    assert 3.0 < t < 6.5, f"training speedup {t} out of the paper's ~5x band"


def test_algorithm2_finds_saturation_knee():
    """Synthetic profile: throughput saturates at num_env=2048; memory keeps
    growing — Algorithm 2 must not pick a post-knee config."""

    def profile(bench, gpg, ne):
        if gpg > 4:
            return ProfilePoint(False, 0.0, 0.0)     # too small to run
        top = 1000.0 * min(ne, 2048) ** 0.9 / gpg ** 0.2
        mem = ne * 1e6 / gpg
        return ProfilePoint(True, top, mem)

    trace = explore(profile, "AT", num_gpu=4, alpha=0.1)
    ne, gpg = trace.best_config
    assert ne <= 4096
    assert gpg <= 4
    assert trace.best_throughput > 0


def test_algorithm2_respects_runnability():
    def profile(bench, gpg, ne):
        return ProfilePoint(gpg == 1 and ne == 128, 10.0, 1.0)
    trace = explore(profile, "AT", num_gpu=1)
    assert trace.best_config == (128, 1)
