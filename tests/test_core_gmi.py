import numpy as np
import pytest

from repro.core.cost_model import (WorkloadProfile, best_lgr, lgr_time_har,
                                   lgr_time_mpr, lgr_time_mrr,
                                   serving_speedup_tcg_over_tdg,
                                   training_speedup_tcg_over_tdg)
from repro.core.gmi import GMIManager
from repro.core.placement import (plan_async, plan_tcg_ex_training,
                                  plan_tcg_serving, plan_tdg_serving,
                                  select_reduction_strategy)
from repro.core.selection import ProfilePoint, explore


def test_manager_registration_and_mapping():
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)
        mgr.set_gpu(gid, gpu)
    assert mgr.gmi_to_gpu_mapping("trainer") == [[0, 1], [2, 3]]
    assert mgr.gmis[0].num_devices == 2
    with pytest.raises(ValueError):
        mgr.add_gmi(0)
    # overcommit: a 5th half-GPU instance on gpu 0 must fail
    mgr.add_gmi(9, "trainer", 0.75)
    with pytest.raises(ValueError):
        mgr.set_gpu(9, 0)


def test_algorithm1_cases():
    # paper Algorithm 1, line-by-line behaviours
    assert select_reduction_strategy([[0, 1, 2]]) == "mpr"
    assert select_reduction_strategy([[0], [1]]) == "mrr"
    assert select_reduction_strategy([[0, 1], [2, 3], [4, 5]]) == "mrr"
    assert select_reduction_strategy([[0, 1, 2], [3, 4]]) == "har"
    assert select_reduction_strategy([[0, 1, 2], [3, 4, 5]]) == "har"


def test_layout_templates():
    tcg = plan_tcg_serving(2, 3, devices=list(range(12)), devices_per_gpu=6)
    assert len(tcg.serving_gmis) == 6
    tdg = plan_tdg_serving(2, 2, devices=list(range(20)),
                           devices_per_gpu=10)
    roles = {g.role for g in tdg.manager.gmis.values()}
    assert roles == {"simulator", "agent"}
    ex = plan_tcg_ex_training(2, 2, devices=list(range(8)),
                              devices_per_gpu=4)
    assert ex.reduction_strategy() == "mrr"       # t=2 == g=2
    ex2 = plan_tcg_ex_training(2, 3, devices=list(range(12)),
                               devices_per_gpu=6)
    assert ex2.reduction_strategy() == "har"      # t=3 > g=2
    asy = plan_async(4, 2, 2, devices=list(range(16)), devices_per_gpu=4)
    assert len(asy.serving_gmis) == 4 and len(asy.trainer_gmis) == 4


def test_lgr_cost_model_orderings():
    # Table 2: with NCCL bandwidth >> host bandwidth, HAR beats MPR, and the
    # HAR advantage grows with more instances per GPU
    M, B1, B2 = 1.5e6, 5e9, 200e9
    assert lgr_time_har(4, 4, M, B1, B2) < lgr_time_mpr(4, 4, M, B1, B2)
    assert best_lgr(2, 8, M, B1, B2) in ("har", "mpr")  # mrr infeasible t>g
    # absolute HAR saving exists at every scale (Table 2 with B2 >> B1)
    for g, t in [(2, 2), (4, 4), (8, 4)]:
        assert lgr_time_har(g, t, M, B1, B2) < lgr_time_mpr(g, t, M, B1, B2)
    # and HAR's cross-GPU stage rides the fast interconnect: doubling B2
    # shrinks HAR time but leaves MPR untouched
    assert lgr_time_har(4, 4, M, B1, 2 * B2) < lgr_time_har(4, 4, M, B1, B2)
    assert lgr_time_mpr(4, 4, M, B1, 2 * B2) == lgr_time_mpr(4, 4, M, B1, B2)


def test_paper_speedup_claims():
    s = serving_speedup_tcg_over_tdg()
    t = training_speedup_tcg_over_tdg()
    assert 2.0 < s < 3.2, f"serving speedup {s} out of the paper's ~2.5x band"
    assert 3.0 < t < 6.5, f"training speedup {t} out of the paper's ~5x band"


def test_algorithm2_finds_saturation_knee():
    """Synthetic profile: throughput saturates at num_env=2048; memory keeps
    growing — Algorithm 2 must not pick a post-knee config."""

    def profile(bench, gpg, ne):
        if gpg > 4:
            return ProfilePoint(False, 0.0, 0.0)     # too small to run
        top = 1000.0 * min(ne, 2048) ** 0.9 / gpg ** 0.2
        mem = ne * 1e6 / gpg
        return ProfilePoint(True, top, mem)

    trace = explore(profile, "AT", num_gpu=4, alpha=0.1)
    ne, gpg = trace.best_config
    assert ne <= 4096
    assert gpg <= 4
    assert trace.best_throughput > 0


def test_algorithm2_respects_runnability():
    def profile(bench, gpg, ne):
        return ProfilePoint(gpg == 1 and ne == 128, 10.0, 1.0)
    trace = explore(profile, "AT", num_gpu=1)
    assert trace.best_config == (128, 1)


def test_algorithm2_saturation_with_shrinking_memory():
    """Regression: when memory SHRINKS between sweep points while
    throughput still grows, Sat used to explode to ±1e9·r_top via the
    clamped denominator.  A throughput gain at no memory cost must never
    prune — the sweep has to reach the highest-throughput point."""
    mems = {128: 3e6, 256: 2e6, 512: 1e6}    # allocator slack: shrinking

    def profile(bench, gpg, ne):
        if gpg != 1 or ne not in mems:
            return ProfilePoint(False, 0.0, 0.0)
        return ProfilePoint(True, 100.0 * ne, mems[ne])

    trace = explore(profile, "AT", num_gpu=1, gmi_per_gpu_range=(1,),
                    num_env_sweep=(128, 256, 512))
    assert trace.best_config == (512, 1)     # swept to the end
    sats = [s for *_, s in trace.points]
    assert sats[1] == float("inf") and sats[2] == float("inf")


def test_algorithm2_flat_memory_no_gain_prunes_cleanly():
    """Flat memory + no throughput gain must stop the sweep with a
    well-defined Sat (-inf), not a ±1e9 artifact."""
    def profile(bench, gpg, ne):
        return ProfilePoint(gpg == 1, 100.0, 1e6)   # flat top, flat mem

    trace = explore(profile, "AT", num_gpu=1, gmi_per_gpu_range=(1,),
                    num_env_sweep=(128, 256, 512))
    assert trace.best_config == (128, 1)
    assert len(trace.points) == 2            # pruned right after point 2
    assert trace.points[-1][-1] == float("-inf")


def test_profiler_distinguishes_oom_from_genuine_bugs(monkeypatch):
    """Resource exhaustion -> 'not runnable'; a shape bug must raise, not
    be silently reported as an unrunnable config."""
    from repro.core.selection import is_resource_exhausted, make_ppo_profiler

    class FakeOOM(RuntimeError):
        pass

    assert is_resource_exhausted(MemoryError())
    assert is_resource_exhausted(FakeOOM("RESOURCE_EXHAUSTED: while trying"))
    assert is_resource_exhausted(FakeOOM("failed to allocate 2.1GiB"))
    assert not is_resource_exhausted(ValueError("shape mismatch (3,) (4,)"))

    def boom_oom(*a, **k):
        raise FakeOOM("RESOURCE_EXHAUSTED: out of memory allocating arena")

    monkeypatch.setattr("repro.rl.ppo.init_train", boom_oom)
    prof = make_ppo_profiler(iters=1)("BallBalance", 1, 128)
    assert not prof.runnable and prof.memory > 0

    def boom_bug(*a, **k):
        raise ValueError("operands could not be broadcast")

    monkeypatch.setattr("repro.rl.ppo.init_train", boom_bug)
    with pytest.raises(ValueError):
        make_ppo_profiler(iters=1)("BallBalance", 1, 128)


def test_instance_mesh_multi_device_keeps_all_chips():
    """Regression: multi-device GMIs used to contribute only
    device_ids[0] — a resized instance silently lost chips."""
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 2 devices each
        mgr.set_gpu(gid, gpu)
    mesh = mgr.instance_mesh("trainer")
    assert mesh.axis_names == ("gpu", "inst", "dev")
    assert mesh.devices.shape == (2, 2, 2)
    assert sorted(mesh.devices.reshape(-1).tolist()) == list(range(8))


def test_multi_device_instance_mesh_is_reducible():
    """The (gpu, inst, dev) meshes instance_mesh builds for multi-device
    GMIs are first-class in repro.comm (the old 2-axis-only lgr_allreduce
    rejected them): every 3-axis schedule constructs, har3 refuses 2-axis
    grids, and >3-axis grids are still rejected loudly.  Numerical parity
    on real device grids lives in tests/_multidev_checks.py."""
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.comm import lgr_allreduce, make_grad_sync

    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)
        mgr.set_gpu(gid, gpu)
    mesh = mgr.instance_mesh("trainer")
    assert mesh.axis_names == ("gpu", "inst", "dev")
    for strat in ("mpr", "mrr", "har", "har3"):
        assert callable(make_grad_sync(strat, mesh.axis_names))
    with pytest.raises(ValueError, match="3-axis"):
        make_grad_sync("har3", ("gpu", "inst"))
    mesh4 = Mesh(np.arange(8).reshape(1, 2, 2, 2),
                 ("pod", "gpu", "inst", "dev"))
    with pytest.raises(ValueError, match="2-axis .* or 3-axis"):
        lgr_allreduce({"w": jnp.ones((1, 2, 2, 2, 3))}, mesh4, "mrr")


def test_instance_mesh_rejects_mixed_device_counts():
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    mgr.add_gmi(0, "trainer", 0.5)           # 2 devices
    mgr.set_gpu(0, 0)
    mgr.add_gmi(1, "trainer", 0.25)          # 1 device
    mgr.set_gpu(1, 1)
    with pytest.raises(ValueError, match="uniform"):
        mgr.instance_mesh("trainer")


def test_serving_only_layout_has_no_reduction_strategy():
    tcg = plan_tcg_serving(2, 2, devices=list(range(8)), devices_per_gpu=4)
    assert tcg.mpl == []
    assert tcg.reduction_strategy() is None
    with pytest.raises(ValueError, match="no trainer"):
        select_reduction_strategy([])
