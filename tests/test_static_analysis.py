"""The repro.analysis invariant checker: every rule proven to fire on a
bad fixture and stay quiet on a good one, suppression-comment
semantics, the CLI's strict exit code, and — the point of the whole
module — the tier-1 gate that ``src/repro`` + ``benchmarks`` +
``examples`` are finding-free, so the invariants the rules encode
(PRNG discipline, donation safety, hot-path purity, kernel/oracle
parity, fault exhaustiveness, no dead control-plane fields, no tracked
bytecode) hold on every commit."""
import dataclasses
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
for p in (ROOT, SRC):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.analysis import run_analysis  # noqa: E402
from repro.analysis.project import (DeadDecisionFieldRule,  # noqa: E402
                                    FaultKindRule, KernelOracleRule,
                                    TrackedBytecodeRule)
from repro.analysis.rules import (DonationReuseRule,  # noqa: E402
                                  HostSyncRule, PrngReuseRule)

FIX = os.path.join(ROOT, "tests", "analysis_fixtures")


def analyze(*names, rules=None, root=None):
    paths = [os.path.join(FIX, n) for n in names]
    return run_analysis(paths, root=root or FIX, rules=rules)


# ----------------------------------------------------------- prng-reuse ----
def test_prng_bad_fires_per_violation():
    found = analyze("prng_bad.py", rules=[PrngReuseRule()])
    assert [f.rule for f in found] == ["prng-reuse"] * 3
    # one per function: sequential, split-after-sampling, loop reuse
    lines = [f.line for f in found]
    assert len(set(lines)) == 3


def test_prng_good_stays_quiet():
    assert analyze("prng_good.py", rules=[PrngReuseRule()]) == []


# ------------------------------------------------------- donation-reuse ----
def test_donation_bad_fires_for_assigned_and_decorated_jits():
    found = analyze("donation_bad.py", rules=[DonationReuseRule()])
    assert [f.rule for f in found] == ["donation-reuse"] * 2
    msgs = " ".join(f.message for f in found)
    assert "'caches'" in msgs and "'buf'" in msgs


def test_donation_good_stays_quiet():
    assert analyze("donation_good.py", rules=[DonationReuseRule()]) == []


# ------------------------------------------------ host-sync-in-hot-path ----
def test_hostsync_bad_fires_on_every_pattern():
    found = analyze("hostsync_bad.py", rules=[HostSyncRule()])
    assert {f.rule for f in found} == {"host-sync-in-hot-path"}
    msgs = " ".join(f.message for f in found)
    for needle in (".item()", ".block_until_ready()", "copies device data",
                   "host-side timing", "float()"):
        assert needle in msgs, needle
    # .block_until_ready() catches BOTH the method and jax.* module form
    assert len(found) == 7   # incl. both perf_counter sites


def test_hostsync_good_stays_quiet():
    # unmarked functions, constant float(), and allowed deliberate syncs
    assert analyze("hostsync_good.py", rules=[HostSyncRule()]) == []


def test_kernels_dir_is_implicitly_hot(tmp_path):
    kdir = tmp_path / "kernels"
    kdir.mkdir()
    (kdir / "hotfile.py").write_text(
        "def f(x):\n    return float(x)\n")
    found = run_analysis([str(kdir)], root=str(tmp_path),
                         rules=[HostSyncRule()])
    assert [f.rule for f in found] == ["host-sync-in-hot-path"]


# --------------------------------------------------------- suppressions ----
def test_allow_comment_suppresses_same_line_and_line_above(tmp_path):
    p = tmp_path / "s.py"
    p.write_text(
        "import jax\n\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(key, (2,))"
        "  # repro: allow(prng-reuse)\n"
        "    # repro: allow(prng-reuse)\n"
        "    c = jax.random.normal(key, (2,))\n"
        "    return a, b, c\n")
    assert run_analysis([str(p)], root=str(tmp_path),
                        rules=[PrngReuseRule()]) == []


def test_allow_for_a_different_rule_does_not_suppress():
    found = analyze("suppress_wrong.py", rules=[PrngReuseRule()])
    assert [f.rule for f in found] == ["prng-reuse"]


# -------------------------------------------------------- kernel-oracle ----
def test_kernel_bad_fires_pairing_and_index_map_arity():
    found = run_analysis([os.path.join(FIX, "kernel_bad")],
                         root=os.path.join(FIX, "kernel_bad"),
                         rules=[KernelOracleRule()])
    msgs = [f.message for f in found]
    assert any("no ref.py oracle" in m for m in msgs)
    assert any("index_map takes 1 args" in m for m in msgs)
    assert len(found) == 2


def test_kernel_good_pairs_through_ops_aliases():
    root = os.path.join(FIX, "kernel_good")
    found = run_analysis([root], root=root, rules=[KernelOracleRule()])
    assert found == []


def test_envmega_bad_fires_prefetch_arity_over_env_block_grid():
    # the env-megakernel idiom: rank-1 env-block grid + 1 scalar-prefetch
    # operand — an index_map that forgets the prefetch operand fires,
    # and so does the missing ref.py oracle
    root = os.path.join(FIX, "envmega_bad")
    found = run_analysis([root], root=root, rules=[KernelOracleRule()])
    msgs = [f.message for f in found]
    assert any("no ref.py oracle" in m for m in msgs)
    assert any("index_map takes 1 args" in m
               and "1 scalar-prefetch" in m
               and "expected 2" in m for m in msgs)
    assert len(found) == 2


def test_envmega_good_aliased_ring_kernel_stays_quiet():
    root = os.path.join(FIX, "envmega_good")
    found = run_analysis([root], root=root, rules=[KernelOracleRule()])
    assert found == []


# ----------------------------------------------------------- fault-kind ----
def test_fault_bad_fires_for_unhandled_kind():
    root = os.path.join(FIX, "fault_bad")
    found = run_analysis([root], root=root, rules=[FaultKindRule()])
    assert [f.rule for f in found] == ["fault-kind"]
    assert "mystery_kind" in found[0].message


def test_fault_good_stays_quiet():
    root = os.path.join(FIX, "fault_good")
    assert run_analysis([root], root=root, rules=[FaultKindRule()]) == []


# -------------------------------------------------- dead-decision-field ----
def test_dead_field_fires_on_unread_field():
    found = analyze("decision_bad.py", rules=[DeadDecisionFieldRule()])
    assert [f.rule for f in found] == ["dead-decision-field"]
    assert "vestigial_estimate" in found[0].message


def test_getattr_string_counts_as_a_read():
    assert analyze("decision_good.py",
                   rules=[DeadDecisionFieldRule()]) == []


def test_decision_projected_throughput_removed():
    """Regression for the dead-field sweep: the controller's Decision
    carried a projected_throughput nothing ever consumed (the analyzer
    proved it); it is gone and must stay gone."""
    from repro.core.controller import Decision
    names = {f.name for f in dataclasses.fields(Decision)}
    assert "projected_throughput" not in names
    d = Decision(num_env=4, gmi_per_gpu=1, serving_gpus=1, reason="t")
    assert d.layout_changed is True and d.seq == 0


# ----------------------------------------------------- tracked-bytecode ----
def _git_ok(cwd):
    try:
        return subprocess.run(["git", "--version"], cwd=cwd,
                              capture_output=True).returncode == 0
    except OSError:
        return False


@pytest.fixture
def tmp_repo(tmp_path):
    if not _git_ok(str(tmp_path)):
        pytest.skip("git unavailable")
    subprocess.run(["git", "init", "-q", str(tmp_path)], check=True)
    return tmp_path


def test_tracked_bytecode_fires_in_a_dirty_repo(tmp_repo):
    (tmp_repo / ".gitignore").write_text("__pycache__/\n*.py[cod]\n")
    (tmp_repo / "mod.pyc").write_bytes(b"\x00")
    subprocess.run(["git", "-C", str(tmp_repo), "add", "-f", ".gitignore",
                    "mod.pyc"], check=True)
    found = run_analysis([], root=str(tmp_repo),
                         rules=[TrackedBytecodeRule()])
    assert [f.rule for f in found] == ["tracked-bytecode"]
    assert found[0].path == "mod.pyc"


def test_tracked_bytecode_requires_gitignore_patterns(tmp_repo):
    (tmp_repo / ".gitignore").write_text("*.log\n")
    found = run_analysis([], root=str(tmp_repo),
                         rules=[TrackedBytecodeRule()])
    assert len(found) == 2
    assert all(f.path == ".gitignore" for f in found)


def test_tracked_bytecode_inert_below_the_toplevel():
    # fixture/test runs rooted in a subdirectory must not drag the
    # enclosing repo's hygiene into their findings
    assert run_analysis([], root=FIX, rules=[TrackedBytecodeRule()]) == []


# ------------------------------------------------------------------ CLI ----
def _cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "repro.analysis", *argv],
                          cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=120)


def test_cli_strict_exits_nonzero_on_findings():
    proc = _cli("--strict", os.path.join(FIX, "prng_bad.py"))
    assert proc.returncode == 1
    assert "prng-reuse" in proc.stdout


def test_cli_nonstrict_reports_but_exits_zero():
    proc = _cli(os.path.join(FIX, "prng_bad.py"))
    assert proc.returncode == 0
    assert "prng-reuse" in proc.stdout


def test_cli_json_output():
    import json
    proc = _cli("--json", os.path.join(FIX, "prng_bad.py"))
    rows = json.loads(proc.stdout)
    assert rows and all(r["rule"] == "prng-reuse" for r in rows)
    assert {"rule", "path", "line", "message"} <= set(rows[0])


# ------------------------------------------------------ the tier-1 gate ----
def test_repo_tree_is_finding_free():
    """`python -m repro.analysis --strict src/repro benchmarks examples`
    must stay clean: every invariant the rules encode holds on the
    committed tree (this is the gate that keeps the real fixes of this
    PR — bench PRNG reuse, the trainer's per-batch float() sync, the
    dead Decision field — from regressing)."""
    paths = [os.path.join(ROOT, d) for d in
             ("src/repro", "benchmarks", "examples")
             if os.path.isdir(os.path.join(ROOT, d))]
    found = run_analysis(paths, root=ROOT)
    assert found == [], "\n" + "\n".join(f.format() for f in found)


def test_bench_preflight_delegates_to_the_analyzer():
    from benchmarks.run import _analysis_findings
    assert _analysis_findings(ROOT) == []
