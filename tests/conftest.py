# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see
# the real single device.  Multi-device tests spawn subprocesses with their
# own flags (tests/test_dist_multidev.py).
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
