# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see
# the real single device.  Multi-device tests spawn subprocesses with their
# own flags (tests/test_dist_multidev.py).
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled_executables_between_modules():
    # The full suite compiles hundreds of distinct XLA programs; keeping
    # every executable's JIT-code pages live for the whole run has
    # segfaulted LLVM during late-suite compiles.  Modules don't share
    # compilations, so dropping the caches at module boundaries bounds
    # the live-code footprint at the cost of a re-trace.
    yield
    import jax
    jax.clear_caches()
