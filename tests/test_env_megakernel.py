"""Env megakernel: Pallas kernel vs oracle, reset-path equivalence, the
zero-copy producer (collect_ring -> ChannelRing slot), and the
multi-agent shared-world family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import (all_env_names, make_env, make_multi_agent_env)
from repro.kernels import ops
from repro.kernels.env_megakernel import mega_step_ring
from repro.kernels.ref import env_mega_step_ref
from repro.models.policy import init_policy, policy_apply
from repro.rl.rollout import collect, collect_ring


def _mega_args(env, num_envs, key=0):
    state, obs = env.reset(jax.random.PRNGKey(key), num_envs=num_envs)
    mc = env.mega
    kw = dict(chain=mc.chain, task=mc.task, substeps=env.spec.substeps,
              dt=env.spec.dt, max_episode_len=env.spec.max_episode_len)
    return state, obs, mc, kw


def _ring(T, S, N, spec, fill=0.0):
    return {"obs": jnp.full((T, S * N, spec.obs_dim), fill),
            "actions": jnp.full((T, S * N, spec.act_dim), fill),
            "rewards": jnp.full((T, S * N), fill),
            "dones": jnp.full((T, S * N), fill)}


def test_env_mega_step_matches_env_mega_step_ref():
    """Pallas megakernel (interpret) == vmapped-materialized oracle: all
    ten step outputs AND the four ring-slot writes, with untouched ring
    cells surviving the aliased call (slot 1 of 2, sentinel fill)."""
    env = make_env("Ant")
    N, T, S, slot, step_t = 8, 4, 2, 1, 2
    state, obs, mc, kw = _mega_args(env, N)
    # force a done inside the batch so the predicated reset runs
    state = state._replace(
        t=state.t.at[3].set(env.spec.max_episode_len - 1))
    a = jax.random.uniform(jax.random.PRNGKey(5),
                           (N, env.spec.act_dim), minval=-1.5, maxval=1.5)
    # the ops wrapper DONATES the ring dict — two independent allocations
    out_k = ops.env_mega_step(*state, a, obs, _ring(T, S, N, env.spec,
                                                    fill=-7.0),
                              step_t, slot, mc.sensor, mc.tgt, mc.masses,
                              mc.lengths, block_envs=4, interpret=True,
                              **kw)
    out_r = env_mega_step_ref(*state, a, obs, _ring(T, S, N, env.spec,
                                                    fill=-7.0),
                              step_t, slot, mc.sensor, mc.tgt, mc.masses,
                              mc.lengths, **kw)
    for k, (xk, xr) in enumerate(zip(out_k[:10], out_r[:10])):
        np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                                   atol=2e-5, err_msg=f"output {k}")
    for c in ("obs", "actions", "rewards", "dones"):
        np.testing.assert_allclose(np.asarray(out_k[10][c]),
                                   np.asarray(out_r[10][c]),
                                   atol=2e-5, err_msg=c)
        # rows outside (step_t, slot) keep the sentinel: aliased ring
        # buffers pass through, they are not re-zeroed
        got = np.asarray(out_k[10][c])
        assert (got[0] == -7.0).all() and (got[3] == -7.0).all()
        assert (got[step_t, :N] == -7.0).all()


@pytest.mark.parametrize("name", all_env_names())
def test_mega_step_ring_matches_oracle_all_envs(name):
    """The fused XLA sibling (shared _step_core) agrees with the oracle
    for every suite env, including a forced auto-reset."""
    env = make_env(name)
    N, T = 6, 1
    state, obs, mc, kw = _mega_args(env, N)
    state = state._replace(
        t=state.t.at[0].set(env.spec.max_episode_len - 1))
    a = jax.random.uniform(jax.random.PRNGKey(3),
                           (N, env.spec.act_dim), minval=-1, maxval=1)
    bufs = _ring(T, 1, N, env.spec)
    out_x = mega_step_ring(*state, a, obs, dict(bufs), 0, 0, mc.sensor,
                           mc.tgt, mc.masses, mc.lengths, **kw)
    out_r = env_mega_step_ref(*state, a, obs, dict(bufs), 0, 0, mc.sensor,
                              mc.tgt, mc.masses, mc.lengths, **kw)
    for k, (xx, xr) in enumerate(zip(out_x[:10], out_r[:10])):
        np.testing.assert_allclose(np.asarray(xx), np.asarray(xr),
                                   atol=2e-5, err_msg=f"{name} output {k}")
    for c in ("obs", "actions", "rewards", "dones"):
        np.testing.assert_allclose(np.asarray(out_x[10][c]),
                                   np.asarray(out_r[10][c]), atol=2e-5)


def test_vector_env_megakernel_matches_vmap():
    """VectorEnv(megakernel=True).step tracks the vmap path step for
    step across auto-resets (shared counter-based PRNG)."""
    env_v = make_env("Humanoid")
    env_m = env_v.with_megakernel(True)
    sv, ov = env_v.reset(jax.random.PRNGKey(2), num_envs=8)
    sm, om = env_m.reset(jax.random.PRNGKey(2), num_envs=8)
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(om))
    key = jax.random.PRNGKey(9)
    for _ in range(12):
        key, k = jax.random.split(key)
        a = jax.random.uniform(k, (8, env_v.spec.act_dim),
                               minval=-1, maxval=1)
        sv, ov, rv, dv = env_v.step(sv, a)
        sm, om, rm, dm = env_m.step(sm, a)
        np.testing.assert_allclose(np.asarray(ov), np.asarray(om),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(rv), np.asarray(rm),
                                   atol=2e-4)
        np.testing.assert_array_equal(np.asarray(dv) != 0,
                                      np.asarray(dm) != 0)


@pytest.mark.parametrize("name", all_env_names())
@pytest.mark.parametrize("megakernel", [False, True])
def test_post_done_state_equals_fresh_reset(name, megakernel):
    """Property: the state an env lands in after ``done`` is EXACTLY the
    ``reset_fn(seed, resets + 1)`` state — on both step paths, for every
    suite env (the counter-based reset contract)."""
    env = make_env(name, megakernel=megakernel)
    N = 4
    state, _ = env.reset(jax.random.PRNGKey(11), num_envs=N)
    state = state._replace(
        t=jnp.full((N,), env.spec.max_episode_len - 1, jnp.int32))
    a = jax.random.uniform(jax.random.PRNGKey(4),
                           (N, env.spec.act_dim), minval=-1, maxval=1)
    state2, _, _, done = env.step(state, a)
    assert bool(jnp.all(done != 0))
    fresh = jax.vmap(env._reset_fn)(state.seed, state.resets + 1)
    for leaf_got, leaf_want, nm in zip(
            (state2.q, state2.qd, state2.root, state2.prev_action,
             state2.t, state2.resets),
            (fresh.q, fresh.qd, fresh.root, fresh.prev_action,
             fresh.t, fresh.resets),
            ("q", "qd", "root", "prev_action", "t", "resets")):
        np.testing.assert_array_equal(np.asarray(leaf_got),
                                      np.asarray(leaf_want), err_msg=nm)


@pytest.mark.parametrize("name", all_env_names())
def test_never_done_trajectory_invariant_to_reset_style(name):
    """When no env ever terminates, the predicated reset (megakernel:
    fresh state only under the done predicate) and the materialized
    reset (vmap: fresh state computed every step, discarded by where)
    must be observationally indistinguishable."""
    env_v = make_env(name)
    env_m = env_v.with_megakernel(True)
    sv, _ = env_v.reset(jax.random.PRNGKey(0), num_envs=4)
    sm, _ = env_m.reset(jax.random.PRNGKey(0), num_envs=4)
    a = jnp.zeros((4, env_v.spec.act_dim))      # calm actions: no falls
    for _ in range(5):
        sv, ov, rv, dv = env_v.step(sv, a)
        sm, om, rm, dm = env_m.step(sm, a)
        assert not bool(jnp.any(dv)) and not bool(jnp.any(dm))
        np.testing.assert_allclose(np.asarray(ov), np.asarray(om),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(rv), np.asarray(rm),
                                   atol=2e-4)
    np.testing.assert_allclose(np.asarray(sv.q), np.asarray(sm.q),
                               atol=2e-5)
    np.testing.assert_array_equal(np.asarray(sv.resets),
                                  np.asarray(sm.resets))


def test_collect_ring_matches_collect():
    """The zero-copy producer writes exactly the Trajectory the staged
    path stages: ring slot contents == collect's traj, bootstrap ==
    last_value, same final state."""
    ne, T, S, slot = 8, 6, 2, 1
    env_v = make_env("Ant")
    env_m = env_v.with_megakernel(True)
    spec = env_v.spec
    params = init_policy(jax.random.key(0), spec.policy_dims)
    sv, ov = env_v.reset(jax.random.PRNGKey(1), num_envs=ne)
    sm, om = env_m.reset(jax.random.PRNGKey(1), num_envs=ne)
    key = jax.random.PRNGKey(2)
    traj, sv, ov, last_value, _ = collect(params, env_v, sv, ov, key, T)
    bufs = _ring(T, S, ne, spec, fill=-3.0)
    bufs, sm, om, boot, _ = collect_ring(params, env_m, sm, om, key, T,
                                         bufs, slot)
    lo, hi = slot * ne, (slot + 1) * ne
    np.testing.assert_allclose(np.asarray(bufs["obs"][:, lo:hi]),
                               np.asarray(traj.obs), atol=2e-5)
    np.testing.assert_allclose(np.asarray(bufs["actions"][:, lo:hi]),
                               np.asarray(traj.actions), atol=2e-5)
    np.testing.assert_allclose(np.asarray(bufs["rewards"][:, lo:hi]),
                               np.asarray(traj.rewards), atol=2e-4)
    np.testing.assert_array_equal(np.asarray(bufs["dones"][:, lo:hi]),
                                  np.asarray(traj.dones))
    # the OTHER slot keeps its sentinel: the producer wrote only its slot
    assert (np.asarray(bufs["obs"][:, :ne]) == -3.0).all()
    np.testing.assert_allclose(np.asarray(boot), np.asarray(last_value),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(om), np.asarray(ov), atol=2e-5)
    np.testing.assert_allclose(np.asarray(sm.q), np.asarray(sv.q),
                               atol=2e-5)


def test_collect_ring_rejects_vmap_env():
    env = make_env("Ant")
    with pytest.raises(ValueError, match="megakernel"):
        collect_ring(None, env, None, None, None, 2, {}, 0)


def test_pipeline_produce_delivers_and_spills():
    """MultiChannelPipeline.produce: the producer writes the ring's own
    slot storage; flush delivers it like a pushed Experience, and a full
    ring spills (lossless) instead of dropping."""
    from repro.core.channels import MultiChannelPipeline
    ne, T = 4, 3
    env = make_env("BallBalance", megakernel=True)
    spec = env.spec
    params = init_policy(jax.random.key(0), spec.policy_dims)
    pipe = MultiChannelPipeline([0], [1], ring_slots=1, use_pallas=False)

    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=ne)
    hold = {"s": state, "o": obs, "k": jax.random.PRNGKey(7)}

    def producer(bufs, slot):
        bufs, hold["s"], hold["o"], boot, hold["k"] = collect_ring(
            params, env, hold["s"], hold["o"], hold["k"], T, bufs, slot)
        return bufs, boot, 5

    pipe.produce(0, T, ne, spec.obs_dim, spec.act_dim, producer)
    pipe.produce(0, T, ne, spec.obs_dim, spec.act_dim, producer)
    assert pipe.spill_count == 1            # slot 1 of 1 was still unread
    out = pipe.flush()
    exps = [e for batch in out.values() for e in batch]
    total = sum(int(e.rewards.size) for e in exps)
    assert total == 2 * T * ne              # both slots delivered
    for e in exps:
        assert e.obs.shape[-1] == spec.obs_dim
        assert int(e.actor_version.max()) == 5
        assert bool(jnp.all(jnp.isfinite(e.obs)))


def test_pipeline_produce_rejects_overlap():
    from repro.core.channels import MultiChannelPipeline
    pipe = MultiChannelPipeline([0], [1], overlap=True)
    with pytest.raises(ValueError, match="blocking"):
        pipe.produce(0, 2, 2, 3, 2, lambda bufs, slot: (bufs, 0, 0))


def test_async_runner_megakernel_matches_vmap_runner():
    """A megakernel AsyncRunner (direct-produce rounds) trains the same
    as the staged vmap runner: same losses, same sample accounting."""
    from repro.rl.a3c import AsyncRunner
    kw = dict(serving_gmis=[0], trainer_gmis=[1], num_envs=8,
              num_steps=4, seed=3)
    env = make_env("Ant")
    r_v = AsyncRunner(env, **kw)
    r_m = AsyncRunner(env.with_megakernel(True), **kw)
    for _ in range(2):
        ls_v, _ = r_v.round()
        ls_m, _ = r_m.round()
        np.testing.assert_allclose(np.asarray(ls_m), np.asarray(ls_v),
                                   atol=1e-3)
    assert r_m.predictions == r_v.predictions == 2 * 4 * 8
    assert r_m.trained_samples == r_v.trained_samples


def test_make_async_runner_megakernel_flag():
    from repro.core.placement import plan_async
    from repro.launch.steps import make_async_runner
    layout = plan_async(2, 1, 2, devices=list(range(4)),
                        devices_per_gpu=2)
    env = make_env("Ant")
    runner = make_async_runner(env, layout, megakernel=True, num_envs=8,
                               num_steps=2)
    assert runner.env.megakernel
    runner.round()
    assert runner.predictions == 2 * 8 * len(layout.serving_gmis)


# ---------------------------------------------------- multi-agent family --
def test_multi_agent_shapes_and_policy_compat():
    K = 3
    env = make_multi_agent_env("Anymal", num_agents=K)
    assert env.spec.obs_dim == make_env("Anymal").spec.obs_dim
    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=2 * K)
    assert obs.shape == (2 * K, env.spec.obs_dim)
    params = init_policy(jax.random.key(0), env.spec.policy_dims)
    mu, log_std, value = policy_apply(params, obs)
    assert mu.shape == (2 * K, env.spec.act_dim)
    a = jnp.zeros((2 * K, env.spec.act_dim))
    state, obs, rew, done = env.step(state, a)
    assert obs.shape == (2 * K, env.spec.obs_dim)
    assert rew.shape == (2 * K,) and done.shape == (2 * K,)
    assert bool(jnp.all(jnp.isfinite(obs)))


def test_multi_agent_world_shared_done_and_reset():
    K = 2
    env = make_multi_agent_env("Ant", num_agents=K)
    state, _ = env.reset(jax.random.PRNGKey(1), num_envs=4 * K)
    state = state._replace(
        t=jnp.full((4,), env.spec.max_episode_len - 1, jnp.int32))
    a = jnp.zeros((4 * K, env.spec.act_dim))
    state2, _, _, done = env.step(state, a)
    d = np.asarray(done).reshape(4, K)
    assert (d != 0).all()                   # every agent of every world
    assert int(state2.t.max()) == 0         # worlds reset together


def test_multi_agent_cross_agent_coupling():
    """Agent 0's action reaches agent 1's observation through the shared
    chain dynamics — one simulation, not K independent ones."""
    K = 2
    env = make_multi_agent_env("Ant", num_agents=K)
    state, _ = env.reset(jax.random.PRNGKey(2), num_envs=K)
    a0 = jnp.zeros((K, env.spec.act_dim))
    a1 = a0.at[0].set(1.0)                  # only agent 0 acts
    o_base = o_kick = None
    s_b, s_k = state, state
    for _ in range(3):                      # let coupling propagate
        s_b, o_base, _, _ = env.step(s_b, a0)
        s_k, o_kick, _, _ = env.step(s_k, a1)
    diff = float(jnp.max(jnp.abs(o_kick[1] - o_base[1])))
    assert diff > 1e-4, "agent 0's action never reached agent 1's obs"


def test_multi_agent_divisibility_and_megakernel_guard():
    env = make_multi_agent_env("Ant", num_agents=3)
    with pytest.raises(ValueError, match="multiple"):
        env.reset(jax.random.PRNGKey(0), num_envs=4)
    with pytest.raises(ValueError, match="vmap-only"):
        env.with_megakernel(True)
    assert env.with_megakernel(False) is env
