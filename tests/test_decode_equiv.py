"""Prefill + decode must reproduce the full forward pass exactly — the
strongest end-to-end correctness property for every cache type (KV, ring,
mLSTM/sLSTM state, Mamba2 state, zamba shared-attn stacked caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T

V = 64
CASES = {
    "dense": ModelConfig(name="dense", num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=2, d_ff=128, vocab_size=V),
    "dense-window": ModelConfig(name="w", num_layers=2, d_model=64,
                                num_heads=4, num_kv_heads=2, d_ff=128,
                                vocab_size=V, sliding_window=8),
    "gemma-style": ModelConfig(name="g", num_layers=2, d_model=64,
                               num_heads=4, num_kv_heads=2, d_ff=128,
                               vocab_size=V, local_global=True,
                               sliding_window=8, attn_softcap=50.0,
                               final_softcap=30.0, tie_embeddings=True),
    "qkv-bias": ModelConfig(name="q", num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, d_ff=128, vocab_size=V,
                            qkv_bias=True),
    "moe-nodrop": ModelConfig(name="m", num_layers=2, d_model=64,
                              num_heads=4, num_kv_heads=2, d_ff=64,
                              vocab_size=V, num_experts=4,
                              experts_per_token=2, moe_capacity_factor=8.0),
    "xlstm": ModelConfig(name="x", d_model=64, num_heads=4, num_kv_heads=4,
                         d_ff=0, vocab_size=V,
                         block_pattern=("mlstm",) * 3 + ("slstm",),
                         num_super=2),
    "xlstm-pf1": ModelConfig(name="x1", d_model=64, num_heads=4,
                             num_kv_heads=4, d_ff=0, vocab_size=V,
                             ssm_expansion=1,
                             block_pattern=("mlstm", "slstm"), num_super=1),
    "zamba": ModelConfig(name="z", d_model=64, num_heads=4, num_kv_heads=4,
                         d_ff=128, vocab_size=V, ssm_state_dim=16,
                         block_pattern=("mamba2",) * 2 + ("attn_shared",),
                         num_super=2),
}


@pytest.mark.parametrize("case", list(CASES))
def test_prefill_decode_equals_forward(case):
    cfg = CASES[case]
    key = jax.random.key(7)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, V)
    params = T.init_model(key, cfg)
    full, _ = T.forward(params, cfg, {"tokens": toks})
    P = S - 4
    lg, caches = T.prefill(params, cfg, {"tokens": toks[:, :P]}, max_seq=S)
    np.testing.assert_allclose(lg, full[:, P - 1], rtol=4e-4, atol=4e-4)
    for i in range(4):
        pos = jnp.full((B,), P + i, jnp.int32)
        lg, caches = T.decode_step(params, cfg, toks[:, P + i], pos, caches)
        np.testing.assert_allclose(lg, full[:, P + i], rtol=4e-4, atol=4e-4)


def test_window_override_long_context_decode():
    """Sliding-window serving variant: decode with a ring cache must match a
    model whose every layer is windowed."""
    cfg = CASES["dense"].replace(sliding_window=8)
    key = jax.random.key(8)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S), 0, V)
    params = T.init_model(key, cfg)
    full, _ = T.forward(params, cfg, {"tokens": toks})
    P = S - 6
    lg, caches = T.prefill(params, cfg, {"tokens": toks[:, :P]}, max_seq=S,
                           window_override=8)
    # ring cache: W=8 slots, not S
    sizes = {x.shape[1] for x in jax.tree.leaves(caches)
             if hasattr(x, "shape") and x.ndim >= 2}
    np.testing.assert_allclose(lg, full[:, P - 1], rtol=4e-4, atol=4e-4)
    for i in range(6):
        pos = jnp.full((B,), P + i, jnp.int32)
        lg, caches = T.decode_step(params, cfg, toks[:, P + i], pos, caches,
                                   window_override=8)
        np.testing.assert_allclose(lg, full[:, P + i], rtol=4e-4, atol=4e-4)
