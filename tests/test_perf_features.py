"""Equivalence tests for the §Perf optimizations: they must change the
schedule/layout, never the math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import InputShape
from repro.data import make_batch
from repro.models import transformer as T
from repro.optim import adam_init, adam_update


def _grads(cfg, params, batch, microbatches=1):
    if microbatches == 1:
        return jax.grad(lambda p: T.loss_fn(p, cfg, batch,
                                            remat=False))(params)
    M = microbatches
    mb = jax.tree.map(
        lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)
    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(M):
        b = jax.tree.map(lambda x: x[i], mb)
        g = jax.grad(lambda p: T.loss_fn(p, cfg, b, remat=False))(params)
        acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32) / M,
                           acc, g)
    return acc


def test_microbatch_grads_match_full_batch():
    """Per-token losses are means within each microbatch, so with equal
    microbatch token counts the accumulated gradient equals the full-batch
    gradient."""
    cfg = get_reduced("internlm2-1.8b")
    shape = InputShape("t", 32, 4, "train")
    params = T.init_model(jax.random.key(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
    g1 = _grads(cfg, params, batch, 1)
    g2 = _grads(cfg, params, batch, 2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_decode_unroll_matches_scan():
    cfg = get_reduced("qwen2-72b")
    key = jax.random.key(1)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    params = T.init_model(key, cfg)
    P_len = S - 3
    lg, c_scan = T.prefill(params, cfg, {"tokens": toks[:, :P_len]},
                           max_seq=S)
    c_unroll = jax.tree.map(lambda x: x, c_scan)
    for i in range(3):
        pos = jnp.full((B,), P_len + i, jnp.int32)
        l_s, c_scan = T.decode_step(params, cfg, toks[:, P_len + i], pos,
                                    c_scan, unroll=False)
        l_u, c_unroll = T.decode_step(params, cfg, toks[:, P_len + i], pos,
                                      c_unroll, unroll=True)
        np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_u),
                                   rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(c_scan), jax.tree.leaves(c_unroll)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_moe_sharding_hook_is_noop_without_mesh():
    """set_moe_sharding(None) must leave results bit-identical."""
    from repro.models.moe import set_moe_sharding
    cfg = get_reduced("mixtral-8x7b")
    params = T.init_model(jax.random.key(2), cfg)
    shape = InputShape("t", 32, 2, "train")
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
    set_moe_sharding(None)
    l1 = T.loss_fn(params, cfg, batch, remat=False)
    l2 = T.loss_fn(params, cfg, batch, remat=False)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_per_layer_ring_cache_equivalence():
    """gemma2-style local/global: per-layer ring caches (local layers hold
    only their window) must reproduce full-forward logits exactly."""
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="g", num_layers=4, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      local_global=True, sliding_window=8,
                      attn_softcap=50.0, final_softcap=30.0)
    key = jax.random.key(3)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, 64)
    params = T.init_model(key, cfg)
    full, _ = T.forward(params, cfg, {"tokens": toks})
    P_len = S - 5
    lg, caches = T.prefill(params, cfg, {"tokens": toks[:, :P_len]},
                           max_seq=S, per_layer_cache=True)
    assert isinstance(caches, list)
    assert [c.k.shape[1] for c in caches] == [8, 24, 8, 24]
    for i in range(5):
        pos = jnp.full((B,), P_len + i, jnp.int32)
        lg, caches = T.decode_step(params, cfg, toks[:, P_len + i], pos,
                                   caches, unroll=True)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, P_len + i]),
                                   rtol=4e-4, atol=4e-4)
