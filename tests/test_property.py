"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cost_model import (lgr_time_har, lgr_time_mpr, lgr_time_mrr)
from repro.models.layers import softcap
from repro.rl.rollout import gae

SET = settings(max_examples=25, deadline=None)


@given(st.integers(1, 10), st.integers(1, 6),
       st.floats(0.5, 1.0), st.floats(0.8, 1.0))
@SET
def test_gae_zero_rewards_zero_values_is_zero(T, N, gamma, lam):
    z = jnp.zeros((T, N))
    advs, rets = gae(z, z, z, jnp.zeros((N,)), gamma, lam)
    assert float(jnp.max(jnp.abs(advs))) == 0.0
    assert float(jnp.max(jnp.abs(rets))) == 0.0


@given(st.integers(2, 12), st.floats(0.5, 0.999), st.floats(0.5, 1.0))
@SET
def test_gae_bounded_by_geometric_sum(T, gamma, lam):
    """|adv| <= rmax * (1 + gamma*lam + ...) when values are zero."""
    rewards = jnp.ones((T, 1))
    zeros = jnp.zeros((T, 1))
    advs, _ = gae(rewards, zeros, zeros, jnp.zeros((1,)), gamma, lam)
    bound = 1.0 / (1.0 - gamma * lam) + 1e-4
    assert float(jnp.max(jnp.abs(advs))) <= bound


@given(st.floats(1.0, 100.0), st.lists(st.floats(-1e4, 1e4),
                                       min_size=1, max_size=16))
@SET
def test_softcap_bounded_and_monotone(cap, xs):
    x = jnp.asarray(xs, jnp.float32)
    y = softcap(x, cap)
    assert float(jnp.max(jnp.abs(y))) <= cap * (1 + 1e-6)
    xs_sorted = jnp.sort(x)
    ys = softcap(xs_sorted, cap)
    assert bool(jnp.all(jnp.diff(ys) >= -1e-6))


@given(st.integers(2, 16), st.integers(1, 16), st.floats(1e5, 1e8),
       st.floats(1e9, 1e10), st.floats(5e10, 5e11))
@SET
def test_har_beats_mpr_iff_interconnect_fast_enough(g, t, M, B1, B2):
    """Table 2 algebra: HAR <= MPR exactly when B2 >= t*B1 — the
    interconnect must outrun host staging by the instances-per-GPU factor
    (this is WHY Algorithm 1 keys on the layout)."""
    har = lgr_time_har(g, t, M, B1, B2)
    mpr = lgr_time_mpr(g, t, M, B1, B2)
    if B2 >= t * B1:
        assert har <= mpr * (1 + 1e-9)
    else:
        assert har >= mpr * (1 - 1e-9)


@given(st.integers(2, 8), st.floats(1e5, 1e8), st.floats(1e9, 1e10),
       st.floats(5e10, 5e11))
@SET
def test_mrr_cost_grows_with_instances(g, M, B1, B2):
    assert lgr_time_mrr(g, 2, M, B1, B2) <= lgr_time_mrr(g, 4, M, B1, B2)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(2, 5),
       st.integers(1, 3))
@SET
def test_channels_roundtrip_arbitrary_shapes(T, N, obs_dim, act_dim):
    from repro.core.channels import MultiChannelPipeline
    from repro.rl.a3c import Experience
    exp = Experience(obs=jnp.ones((T, N, obs_dim)),
                     actions=jnp.zeros((T, N, act_dim)),
                     rewards=jnp.arange(T * N, dtype=jnp.float32
                                        ).reshape(T, N),
                     dones=jnp.zeros((T, N)), bootstrap=jnp.ones((N,)),
                     actor_version=jnp.int32(0))
    pipe = MultiChannelPipeline([0], [1])
    pipe.push(0, exp)
    ((dst, batches),) = pipe.flush().items()
    got = batches[0]
    np.testing.assert_array_equal(np.asarray(got.obs), np.asarray(exp.obs))
    np.testing.assert_array_equal(np.asarray(got.rewards),
                                  np.asarray(exp.rewards))


@given(st.integers(0, 3), st.integers(1, 3))
@SET
def test_mlstm_state_decay_monotone(seed, heads):
    """With zero input gate (log_i -> -inf), the state must only decay."""
    from repro.models import ssm
    key = jax.random.key(seed)
    B, S, dh = 1, 4, 8
    q = jax.random.normal(key, (B, heads, S, dh))
    C0 = jnp.eye(dh)[None, None].repeat(heads, 1)
    # directly exercise the chunk: log_i very negative => w_intra ~ 0
    h, C, n, m = ssm._mlstm_chunk(
        q, q, q, jnp.full((B, heads, S), -60.0),
        jnp.full((B, heads, S), jnp.log(0.5)),
        C0, jnp.ones((B, heads, dh)), jnp.zeros((B, heads)))
    # effective (de-stabilized) state C·exp(m) must equal C0 · 0.5^S
    ratio = float(jnp.max(jnp.abs(C))) * float(jnp.exp(m[0, 0]))
    np.testing.assert_allclose(ratio, 0.5 ** S, rtol=1e-4)
