"""Fault-tolerant elastic fleet: every injected fault class recovers
deterministically and losslessly.

The acceptance invariants pinned here:

* a serving or trainer GMI killed mid-epoch loses ZERO experience —
  ``trained_samples (+ poisoned_samples) == predictions`` after recovery
  and finish (spill-not-drop, drain-train re-plan);
* a serving engine killed mid-decode loses ZERO requests — every
  submitted rid completes with status ok/timeout/failed;
* a torn checkpoint is skipped and the previous pair restores
  params/opt_state/version BIT-identically via ``AsyncRunner.restore``;
* the same seeded :class:`FaultPlan` reproduces the same failure AND
  recovery sequence, always.
"""
import numpy as np
import pytest

import jax

from repro.core.placement import plan_async
from repro.envs import make_env
from repro.fault import (KINDS, FaultEvent, FaultPlan, FleetSupervisor,
                         InjectedFault, make_save_crash_hook,
                         tear_checkpoint)
from repro.launch.steps import make_fleet_supervisor

ENV = make_env("Ant")


def build(plan=None, serving_gpus=2, num_gpu=3, probation=10, **kw):
    layout = plan_async(num_gpu, serving_gpus, 2,
                        devices=list(range(2 * num_gpu)),
                        devices_per_gpu=2)
    return make_fleet_supervisor(ENV, layout, plan=plan, num_envs=4,
                                 num_steps=2, probation=probation, **kw)


def assert_lossless(sup):
    r = sup.runner
    assert r.trained_samples + r.poisoned_samples == r.predictions, \
        f"lost {r.predictions - r.trained_samples - r.poisoned_samples} " \
        f"samples\n{sup.summary()}"


# -------------------------------------------------------------- the plan --
def test_fault_plan_random_is_deterministic():
    a = FaultPlan.random(seed=7, rounds=50)
    b = FaultPlan.random(seed=7, rounds=50)
    assert a.events == b.events and len(a.events) > 0
    c = FaultPlan.random(seed=8, rounds=50)
    assert a.events != c.events


def test_fault_plan_take_fires_once_and_respects_rounds():
    plan = FaultPlan([FaultEvent("kill_serving", round=2, target=5)])
    plan.advance(0)
    assert plan.take("kill_serving", target=5) is None   # not due yet
    plan.advance(2)
    assert plan.take("kill_serving", target=4) is None   # wrong target
    ev = plan.take("kill_serving", target=5)
    assert ev is not None and ev.round == 2
    assert plan.take("kill_serving", target=5) is None   # fired once
    assert plan.exhausted and plan.fired == [ev]


def test_fault_plan_wildcards_and_unknown_kind():
    plan = FaultPlan([FaultEvent("engine_fail", round=0)])
    plan.advance(0)
    assert plan.take("engine_fail", target=3) is not None  # None matches any
    with pytest.raises(ValueError):
        FaultEvent("meteor_strike", round=0)
    assert set(KINDS) >= {"kill_serving", "kill_trainer", "engine_fail"}


# ---------------------------------------------------- GMI kill recovery --
def test_serving_gmi_kill_is_lossless_and_quarantines():
    plan = FaultPlan([FaultEvent("kill_serving", round=1)])
    sup = build(plan=plan)
    sup.run(4)
    assert_lossless(sup)
    assert [f["kind"] for f in sup.failures] == ["kill_serving"]
    assert sup.serving_gpus == 1 and sup.num_gpu == 2
    assert len(sup.quarantined) == 1
    assert sup.quarantined[0]["role"] == "serving"
    assert sup.runner.replans == 1
    # the fleet keeps making progress on the reduced pool
    assert sup.runner.trained_samples > 0


def test_trainer_gmi_kill_requeues_experience():
    plan = FaultPlan([FaultEvent("kill_trainer", round=1)])
    sup = build(plan=plan, serving_gpus=1)
    sup.run(4)
    assert_lossless(sup)
    assert [f["kind"] for f in sup.failures] == ["kill_trainer"]
    assert sup.num_gpu == 2 and sup.serving_gpus == 1
    assert sup.quarantined and sup.quarantined[0]["role"] == "trainer"


def test_probation_readmits_the_quarantined_gpu():
    plan = FaultPlan([FaultEvent("kill_serving", round=0)])
    sup = build(plan=plan, probation=2)
    sup.run(5)
    assert_lossless(sup)
    readmits = [r for r in sup.recoveries if r["kind"] == "readmit"]
    assert len(readmits) == 1 and readmits[0]["role"] == "serving"
    # pool restored after probation
    assert sup.num_gpu == 3 and sup.serving_gpus == 2
    assert not sup.quarantined


def test_last_trainer_restarts_in_place():
    # 2 GPUs, 1 serving + 1 trainer: the trainer cannot be quarantined
    plan = FaultPlan([FaultEvent("kill_trainer", round=1)])
    sup = build(plan=plan, serving_gpus=1, num_gpu=2)
    sup.run(3)
    assert_lossless(sup)
    assert sup.num_gpu == 2 and not sup.quarantined
    assert "in place" in sup.recoveries[0]["action"]


def test_same_plan_same_recovery_sequence():
    def run_once():
        plan = FaultPlan.random(seed=3, rounds=5,
                                kinds=("kill_serving", "kill_trainer"),
                                rate=0.5, targets=(0, 1, 2, 3, 100))
        sup = build(plan=plan)
        sup.run(5)
        return ([(f["kind"], f["round"]) for f in sup.failures],
                sup.runner.trained_samples, sup.runner.predictions)
    a, b = run_once(), run_once()
    assert a == b and a[1] == a[2]


# ------------------------------------------------------- channel faults --
def test_channel_drop_retransmits():
    plan = FaultPlan([FaultEvent("channel_drop", round=1)])
    sup = build(plan=plan)
    sup.run(4)
    assert_lossless(sup)
    assert sup.runner.pipe.dropped_flushes == 1
    assert any(f["kind"] == "channel_drop" for f in sup.failures)
    assert sup.runner.poisoned_samples == 0


def test_channel_poison_discards_update_keeps_params_finite():
    plan = FaultPlan([FaultEvent("channel_poison", round=1)])
    sup = build(plan=plan)
    sup.run(4)
    r = sup.runner
    assert r.poisoned_batches >= 1 and r.poisoned_samples > 0
    assert_lossless(sup)   # counted, not silently dropped
    for leaf in jax.tree.leaves(jax.device_get(r.params)):
        assert np.isfinite(leaf).all()
    assert any(rec["kind"] == "channel_poison" for rec in sup.recoveries)


# ------------------------------------------------------- engine failure --
def test_engine_fail_loses_no_request():
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import Request, RequestRouter, ServeEngine
    cfg = ModelConfig(name="f", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)
    engines = [ServeEngine(cfg, params, max_slots=2, max_seq=32,
                           name=f"e{i}") for i in range(3)]
    router = RequestRouter(engines)
    plan = FaultPlan([FaultEvent("engine_fail", round=1, target=1)])
    layout = plan_async(3, 2, 2, devices=list(range(6)), devices_per_gpu=2)
    sup = make_fleet_supervisor(ENV, layout, plan=plan, router=router,
                                num_envs=4, num_steps=2)
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(0, 64, 6), max_new_tokens=5)
            for _ in range(9)]
    for q in reqs:
        router.submit(q)
    sup.plan.advance(1)
    done = sup.drain_serving()
    # zero lost requests: every submitted rid completed, all ok (the
    # retry budget covered the single restart)
    assert {c.rid for c in done} == {q.rid for q in reqs}
    assert all(c.status in ("ok", "timeout", "failed") for c in done)
    assert sum(c.status == "ok" for c in done) == len(reqs)
    assert router.num_engines == 2 and router.failed_engines == 1
    assert any(f["kind"] == "engine_fail" for f in sup.failures)


def test_engine_fail_retry_cap_reports_failed():
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import Request, RequestRouter, ServeEngine
    cfg = ModelConfig(name="f2", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)
    engines = [ServeEngine(cfg, params, max_slots=2, max_seq=32,
                           name=f"e{i}") for i in range(3)]
    router = RequestRouter(engines)
    rng = np.random.default_rng(1)
    req = Request(tokens=rng.integers(0, 64, 6), max_new_tokens=8)
    router.submit(req)
    router.step()                       # admitted into a slot
    holder = next(e for e in engines if e.active_count)
    router.fail_engine(holder, max_retries=1)     # retry 1: restarts
    router.step()
    holder2 = next(e for e in router.engines if e.active_count)
    done = router.fail_engine(holder2, max_retries=1)  # budget exhausted
    assert [c.status for c in done] == ["failed"]
    assert done[0].rid == req.rid and not router.busy


def test_prefill_gmi_kill_mid_migration_loses_zero_requests():
    """A prefill-specialist GMI dies WITH a cache payload still in flight
    on the migration channel: the supervisor classifies it as
    ``prefill_fail`` (not decode-engine death), the dead source's staged
    transfer is evicted, and every request — queued, in flight, or
    already migrated — completes token-identically on the survivors."""
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import (DisaggFront, MigrationPlanner, PrefillEngine,
                             Request, RequestRouter, ServeEngine)
    cfg = ModelConfig(name="pf", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)

    def efac(i, slots=2):
        return ServeEngine(cfg, params, max_slots=slots, max_seq=32,
                           name=f"d{i}")

    def pfac(i):
        return PrefillEngine(cfg, params, max_seq=32, name=f"p{i}")

    router = RequestRouter(engine_factory=efac, num_engines=2)
    front = DisaggFront(
        router, [pfac(0), pfac(1)],
        planner=MigrationPlanner(bandwidth=1e15, latency_s=0.0,
                                 prefill_tok_s=1e-6),   # force migration
        prefill_factory=pfac)
    plan = FaultPlan([FaultEvent("prefill_fail", round=1, target=0)])
    layout = plan_async(3, 2, 2, devices=list(range(6)), devices_per_gpu=2)
    sup = make_fleet_supervisor(ENV, layout, plan=plan, router=front,
                                num_envs=4, num_steps=2)
    rng = np.random.default_rng(5)
    reqs = [Request(tokens=rng.integers(0, 64, 6), max_new_tokens=5)
            for _ in range(8)]
    oracle = {q.rid: router.engines[0].oracle_generate(q) for q in reqs}
    for q in reqs:
        front.submit(q)
    # stage a payload mid-migration from the doomed specialist: prefilled
    # and sent, but not yet delivered when the kill fires
    doomed = front.prefill_engines[0]
    payload = doomed.step()
    front.channel.send(payload, payload.cache, source=doomed)
    assert front.channel.in_flight == 1
    sup.plan.advance(1)
    done = sup.drain_serving()
    # zero lost requests, all token-identical — including the one whose
    # in-flight transfer died with its source
    assert {c.rid for c in done} == {q.rid for q in reqs}
    for c in done:
        assert c.status == "ok" and c.tokens == oracle[c.rid]
    assert front.failed_prefill_engines == 1
    assert len(front.prefill_engines) == 1
    assert front.channel.in_flight == 0
    assert [f["kind"] for f in sup.failures] == ["prefill_fail"]
    assert any(r["kind"] == "prefill_fail" for r in sup.recoveries)


# ----------------------------------------------------- crash and resume --
def test_torn_checkpoint_skipped_previous_restores_bit_identical(tmp_path):
    d = str(tmp_path)
    sup = build()
    sup.run(2)
    runner = sup.runner
    runner.checkpoint(d, step=1)
    want = jax.device_get({"params": runner.params,
                           "opt_state": runner.opt_state,
                           "version": runner.version})
    want_counters = (runner.predictions, runner.trained_samples)
    sup.run(2)                                   # advance past step 1
    runner.checkpoint(d, step=2)
    tear_checkpoint(d, 2, mode="torn_npz")       # newest pair is torn

    fresh = build().runner
    got_step = fresh.restore(d)
    assert got_step == 1                         # torn step 2 skipped
    got = jax.device_get({"params": fresh.params,
                          "opt_state": fresh.opt_state,
                          "version": fresh.version})
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (fresh.predictions, fresh.trained_samples) == want_counters


def test_crash_mid_save_leaves_previous_pair_loadable(tmp_path):
    d = str(tmp_path)
    sup = build()
    sup.run(1)
    runner = sup.runner
    runner.checkpoint(d, step=1)
    with pytest.raises(InjectedFault):
        runner.checkpoint(d, step=2,
                          fault_hook=make_save_crash_hook("before_manifest"))
    fresh = build().runner
    assert fresh.restore(d) == 1                 # orphan npz is invisible


def test_supervised_ckpt_tear_schedule_and_auto_resume(tmp_path):
    d = str(tmp_path)
    plan = FaultPlan([FaultEvent("ckpt_tear", round=4, mode="missing_npz")])
    sup = build(plan=plan, ckpt_dir=d, ckpt_every=2)
    sup.run(6)
    from repro.checkpoint import steps
    assert sup.ckpt_steps == [2, 4, 6]
    assert steps(d) == [2, 6]                    # step 4 torn, skipped
    fresh = build().runner
    assert fresh.restore(d) == 6


def test_restore_empty_dir_returns_none(tmp_path):
    assert build().runner.restore(str(tmp_path)) is None


def test_controller_state_round_trips_through_checkpoint(tmp_path):
    from repro.core.controller import OnlineGMIController
    src = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                              num_env=64)
    from repro.core.controller import RoundSample
    for _ in range(src.cfg.epoch_rounds):
        src.record(RoundSample(samples=256, dt=0.1, occupancy=0.5,
                               spills=0, mem_bytes=1e6))
    state = src.state_dict()
    import json
    state = json.loads(json.dumps(state))        # must be JSON-safe
    dst = OnlineGMIController(num_gpu=2, serving_gpus=1, gmi_per_gpu=1,
                              num_env=8)
    dst.load_state_dict(state)
    assert dst.num_gpu == 4 and dst.serving_gpus == 2
    # num_env follows whatever the controller committed (it may have
    # probed the ladder during the recorded epoch) — the round-trip must
    # reproduce the live value, not the constructor's
    assert dst.gmi_per_gpu == src.gmi_per_gpu
    assert dst.num_env == src.num_env
    assert dst._table.keys() == src._table.keys()
    k = next(iter(src._table))
    assert dst._table[k].point.throughput \
        == pytest.approx(src._table[k].point.throughput)
    assert dst._table[k].epochs == src._table[k].epochs


# --------------------------------------------------- deadline / dup rid --
def test_deadline_expired_request_times_out_without_a_slot():
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine
    cfg = ModelConfig(name="f3", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=32)
    rng = np.random.default_rng(2)
    live = Request(tokens=rng.integers(0, 64, 4), max_new_tokens=6)
    ttl = Request(tokens=rng.integers(0, 64, 4), max_new_tokens=6,
                  deadline_s=0.0)
    eng.submit(live)
    eng.submit(ttl)                     # queued behind the busy slot
    done = eng.run_until_idle()
    st = {c.rid: c for c in done}
    assert st[live.rid].status == "ok" and len(st[live.rid].tokens) == 6
    assert st[ttl.rid].status == "timeout" and st[ttl.rid].tokens == []
    assert eng.timeouts == 1


def test_router_rejects_duplicate_rid():
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import Request, RequestRouter, ServeEngine
    cfg = ModelConfig(name="f4", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)
    router = RequestRouter([ServeEngine(cfg, params, max_slots=2,
                                        max_seq=32)])
    rng = np.random.default_rng(3)
    req = Request(tokens=rng.integers(0, 64, 4), max_new_tokens=2)
    router.submit(req)
    with pytest.raises(ValueError, match="duplicate rid"):
        router.submit(req)
    router.drain()


def test_scale_to_without_factory_warns_of_shortfall():
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import RequestRouter, ServeEngine
    cfg = ModelConfig(name="f5", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)
    router = RequestRouter([ServeEngine(cfg, params, max_slots=2,
                                        max_seq=32)])
    with pytest.warns(RuntimeWarning, match="no engine_factory"):
        assert router.scale_to(3) == 1
