"""Partition-spec rules validated against every architecture on an abstract
16x16 (and 2x16x16) mesh — no devices needed."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.dist.partition import batch_specs, cache_specs, param_specs
from repro.launch.steps import abstract_cache, input_specs
from repro.configs.base import INPUT_SHAPES
from repro.models import transformer as T

def _amesh(sizes, names):
    try:                                  # jax >= 0.5 signature
        return AbstractMesh(sizes, names)
    except TypeError:                     # jax 0.4.x: tuple of (name, size)
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _amesh((16, 16), ("data", "model"))
MESH3 = _amesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisibility(sds_tree, spec_tree, mesh):
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    flat_s = jax.tree.leaves(sds_tree)
    flat_p = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for sds, spec in zip(flat_s, flat_p):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            assert sds.shape[d] % n == 0, (sds.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    params = T.init_abstract(cfg)
    specs = param_specs(params, mesh, fsdp=True)
    _check_divisibility(params, specs, mesh)


@pytest.mark.parametrize("arch", ["qwen2-72b", "mixtral-8x7b", "zamba2-7b",
                                  "xlstm-1.3b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    shape = INPUT_SHAPES["decode_32k"]
    caches = abstract_cache(cfg, shape)
    specs = cache_specs(caches, MESH)
    _check_divisibility(caches, specs, MESH)


def test_tp_sharding_hits_big_matrices():
    """The model axis must actually shard the projections of a big config
    (otherwise the dry-run silently replicates 72B params)."""
    cfg = get_config("qwen2-72b")
    params = T.init_abstract(cfg)
    specs = param_specs(params, MESH, fsdp=True)
    wq_spec = specs["layers"]["attn"]["wq"]["w"]
    assert "model" in jax.tree.leaves(wq_spec, is_leaf=lambda x: x is not None) \
        or "model" in tuple(wq_spec), wq_spec
    assert "data" in tuple(wq_spec)
    # embedding vocab-parallel
    emb = specs["embed"]["table"]
    assert tuple(emb)[0] == "model"


def test_granite_vocab_fallback():
    """49155 doesn't divide 16 — the vocab axis must fall back, not crash."""
    cfg = get_config("granite-moe-1b-a400m")
    params = T.init_abstract(cfg)
    specs = param_specs(params, MESH, fsdp=True)
    emb = tuple(specs["embed"]["table"])
    # replicated (see partition.py: GSPMD gather bug workaround)
    assert "model" not in emb
    _check_divisibility(params, specs, MESH)


def test_batch_specs():
    shape = INPUT_SHAPES["train_4k"]
    cfg = get_config("internlm2-1.8b")
    sds = input_specs(cfg, shape)
    specs = batch_specs(sds, MESH, batch_axes=("data",))
    assert tuple(specs["tokens"])[0] == "data"
    specs3 = batch_specs(sds, MESH3, batch_axes=("pod", "data"))
    assert tuple(specs3["tokens"])[0] == ("pod", "data")
