"""Multi-GMI serving front: queue-depth routing, per-GMI stats, lossless
scale-down, ServingRole (Listing 1), and the acceptance loop — the online
controller scaling serving GMIs under a load ramp, pinned via the
recorded telemetry."""
import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core.controller import ControllerConfig, OnlineGMIController
from repro.core.gmi import GMIManager
from repro.models import transformer as T
from repro.serve import (Request, RequestRouter, ServeEngine, ServingRole,
                         ServingLoad, merge_loads)

V = 64
CFG = ModelConfig(name="d", num_layers=2, d_model=32, num_heads=2,
                  num_kv_heads=2, d_ff=64, vocab_size=V)
PARAMS = T.init_model(jax.random.key(0), CFG)


def make_engine(i, slots=2):
    return ServeEngine(CFG, PARAMS, max_slots=slots, max_seq=32,
                       name=f"e{i}")


def req(rng, gen=4, plen=6):
    return Request(tokens=rng.integers(0, V, plen), max_new_tokens=gen)


# ----------------------------------------------------------------- routing --
def test_routes_by_queue_depth():
    router = RequestRouter([make_engine(0), make_engine(1)])
    rng = np.random.default_rng(0)
    for _ in range(6):
        router.submit(req(rng))
    loads = [e.load for e in router.engines]
    assert loads == [3, 3]          # least-loaded admission balances


def test_router_drain_completes_everything():
    router = RequestRouter([make_engine(0), make_engine(1)])
    rng = np.random.default_rng(1)
    reqs = [req(rng, gen=3 + i % 3) for i in range(7)]
    for r in reqs:
        router.submit(r)
    done = router.drain()
    assert {c.rid for c in done} == {r.rid for r in reqs}
    assert not router.busy


def test_scale_down_loses_no_request():
    router = RequestRouter([make_engine(0), make_engine(1)],
                           engine_factory=make_engine)
    rng = np.random.default_rng(2)
    reqs = [req(rng) for i in range(8)]        # deep queues on both
    for r in reqs:
        router.submit(r)
    router.scale_to(1)                         # retire one worker mid-load
    assert router.num_engines == 1
    done = list(router.completions) + router.drain()
    assert {c.rid for c in done} == {r.rid for r in reqs}
    for c in done:
        assert len(c.tokens) == c.request.max_new_tokens   # never truncated


def test_scale_up_via_factory():
    router = RequestRouter(engine_factory=make_engine, num_engines=1)
    assert router.num_engines == 1
    router.scale_to(3)
    assert router.num_engines == 3
    # without a factory the router cannot grow
    fixed = RequestRouter([make_engine(0)])
    fixed.scale_to(4)
    assert fixed.num_engines == 1


def test_resize_slots_is_lossless():
    router = RequestRouter(engine_factory=make_engine, num_engines=2)
    rng = np.random.default_rng(7)
    reqs = [req(rng) for _ in range(6)]
    for r in reqs:
        router.submit(r)
    router.step()
    assert router.resize_slots(4)
    assert all(e.max_slots == 4 for e in router.engines)
    assert router.num_engines == 2
    done = list(router.completions) + router.drain()
    assert {c.rid for c in done} == {r.rid for r in reqs}
    for c in done:
        assert len(c.tokens) == c.request.max_new_tokens
    # same width is a no-op; engines built without a slots-aware factory
    # cannot resize
    assert not router.resize_slots(4)
    assert not RequestRouter([make_engine(0)]).resize_slots(8)


def test_retired_worker_telemetry_reaches_next_epoch():
    """Scale-down must not hide the retiring worker's drained tokens and
    latencies from the controller — a loaded system would look idle."""
    router = RequestRouter([make_engine(0), make_engine(1)],
                           engine_factory=make_engine)
    rng = np.random.default_rng(8)
    reqs = [req(rng, gen=3) for _ in range(4)]
    for r in reqs:
        router.submit(r)
    router.step()                     # both engines produce tokens
    router.scale_to(1)                # retiring engine drains its slots
    router.drain()
    load = router.take_epoch()
    assert load.tokens == 12          # 4 reqs x 3 tokens, none dropped
    assert load.requests == 4
    # slot capacity reflects the LIVE engine set, not live + retired —
    # phantom slots would mis-key the controller's serving table
    assert load.slots == 2


def test_per_gmi_stats_and_epoch_merge():
    router = RequestRouter([make_engine(0), make_engine(1)])
    rng = np.random.default_rng(3)
    for _ in range(4):
        router.submit(req(rng, gen=3))
    router.drain()
    per = router.per_gmi_stats()
    assert len(per) == 2 and all(s.tokens == 6 for s in per)
    total = router.take_epoch()
    assert total.tokens == 12 and total.requests == 4
    assert total.slots == 4
    # epochs were consumed
    assert router.take_epoch().tokens == 0


def test_merge_loads_empty():
    z = merge_loads([])
    assert z.tokens == 0 and z.tok_s == 0.0


def test_backdated_submit_does_not_rewind_epoch_span():
    """Re-routed requests keep their original LATENCY clock, but the
    epoch span markers must follow the wall clock — otherwise a re-route
    just after an epoch reset inflates the epoch's dt and collapses the
    measured tok/s."""
    from repro.serve.telemetry import ServingTelemetry
    tel = ServingTelemetry(slots=2)
    tel.take_epoch()
    tel.on_submit(1, t=tel.clock() - 100.0)       # arrived long ago
    tel.on_step(0.01, active=1, queued=0, tokens_out=1)
    load = tel.snapshot()
    assert load.dt < 50.0                          # span not rewound
    tel.on_finish(1)
    p50, _ = tel.percentiles()
    assert p50 > 99.0                              # latency clock kept


def test_maybe_replan_reconciles_when_fleet_cannot_follow():
    """A fixed engine list cannot scale; the controller's committed split
    must snap back to the real fleet instead of drifting up every epoch
    (its telemetry divisor would otherwise keep shrinking)."""
    router = RequestRouter([make_engine(0)])       # no factory
    ctrl = OnlineGMIController(num_gpu=4, serving_gpus=1, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    rng = np.random.default_rng(11)
    for _ in range(3):
        for _ in range(4):
            for _ in range(4):
                router.submit(req(rng, gen=6))
            router.step()
        router.maybe_replan(ctrl)
        assert ctrl.serving_gpus == 1              # reconciled every epoch
    assert router.num_engines == 1
    router.drain()


# ------------------------------------------------------------- ServingRole --
def test_serving_role_gmi_run_on_submesh():
    mgr = GMIManager(devices=jax.devices(), devices_per_gpu=1,
                     backend="submesh")
    role = ServingRole(mgr, 0, 0, CFG, PARAMS, max_slots=2, max_seq=32)
    assert mgr.gmis[0].role == "serving"
    assert role.engine.mesh is not None          # MIG-style isolation
    rng = np.random.default_rng(4)
    reqs = [req(rng, gen=4) for _ in range(3)]
    done = role.gmi_run(reqs)
    assert {c.rid for c in done} == {r.rid for r in reqs}
    # engine must be token-identical inside the submesh too
    probe = req(rng, gen=5)
    oracle = role.engine.oracle_generate(probe)
    out = role.gmi_run([probe])[0]
    assert out.tokens == oracle


# -------------------------------------------- controller under a load ramp --
def test_controller_scales_serving_gmis_under_load_ramp():
    """Acceptance: open-loop traffic outruns one engine; the recorded
    telemetry shows sustained backlog; the controller answers by moving
    GPUs to serving (1 -> 2 -> 3); when traffic stops, the idle epochs
    move one back."""
    router = RequestRouter(engine_factory=make_engine, num_engines=1)
    ctrl = OnlineGMIController(num_gpu=4, serving_gpus=1, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=2))
    rng = np.random.default_rng(5)
    recorded = []           # the telemetry the decisions are based on

    def one_epoch(arrivals_per_step):
        for _ in range(4):
            for _ in range(arrivals_per_step):
                router.submit(req(rng, gen=6))
            router.step()
        load = router.take_epoch()
        recorded.append(load)
        return ctrl.observe_serving(load)

    sizes = [router.num_engines]
    for _ in range(8):              # overload: 3 arrivals/step vs ~2 tok/step
        d = one_epoch(3)
        if d is not None and d.layout_changed:
            router.scale_to(d.serving_gpus)
        sizes.append(router.num_engines)
        if router.num_engines == 3:
            break
    assert router.num_engines == 3 and ctrl.serving_gpus == 3
    assert sizes == sorted(sizes)                # monotone ramp up
    # the decisions cite serving backlog, and the recorded telemetry
    # actually shows it (queue growing with every slot busy)
    ups = [d for d in ctrl.decisions if "+1 serving GPU" in d.reason]
    assert len(ups) == 2
    assert all("serving backlog" in d.reason for d in ups)
    assert any(l.backlog > 0 and l.occupancy_mean > 0.9 for l in recorded)
    # measured serving profile accumulated in the controller table
    assert ctrl._serving_table and ctrl.serving_slots >= 2
    assert "serving (gpg=" in ctrl.summary()

    # traffic stops: drain, then idle epochs hand a GPU back
    router.drain()
    router.take_epoch()
    for _ in range(2):
        d = ctrl.observe_serving(router.take_epoch())
    assert d is not None and d.serving_gpus == 2
    assert "serving idle" in d.reason
    router.scale_to(d.serving_gpus)
    assert router.num_engines == 2


def test_maybe_replan_applies_decision():
    router = RequestRouter(engine_factory=make_engine, num_engines=1)
    ctrl = OnlineGMIController(num_gpu=3, serving_gpus=1, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    rng = np.random.default_rng(6)
    changed = False
    for _ in range(3):
        for _ in range(4):
            for _ in range(4):
                router.submit(req(rng, gen=6))
            router.step()
        changed = router.maybe_replan(ctrl) or changed
        if changed:
            break
    assert changed and router.num_engines == ctrl.serving_gpus == 2
    router.drain()


def test_maybe_replan_applies_slot_probe_by_resizing():
    """At max split the controller's decision carries a slot-ladder probe;
    maybe_replan applies it by rebuilding the engines wider (the factory
    accepts ``slots``)."""
    router = RequestRouter(engine_factory=make_engine, num_engines=1)
    ctrl = OnlineGMIController(num_gpu=2, serving_gpus=1, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    rng = np.random.default_rng(9)
    changed = False
    for _ in range(3):
        for _ in range(4):
            for _ in range(4):
                router.submit(req(rng, gen=6))
            router.step()
        changed = router.maybe_replan(ctrl)
        if changed:
            break
    assert changed
    assert all(e.max_slots == 4 for e in router.engines)   # 2 -> 4
    d = ctrl.decisions[-1]
    assert "probe slots=4" in d.reason and d.serving_gpus == 1
    router.drain()


# --------------------------------------------- controller serving units ----
def _load(backlog=0, occ=0.5, q=0.0, qmax=0, tokens=100, dt=1.0, slots=4,
          p95=0.05):
    return ServingLoad(dt=dt, tokens=tokens, requests=4, queue_depth_mean=q,
                       queue_depth_max=qmax, occupancy_mean=occ,
                       backlog=backlog, p50_s=p95 / 2, p95_s=p95,
                       slots=slots)


def test_backlog_stops_at_max_split_then_probes_slots():
    ctrl = OnlineGMIController(num_gpu=3, serving_gpus=2, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    # router-level load: 4 total slots over 2 serving instances -> 2 each
    d = ctrl.observe_serving(_load(backlog=3, occ=1.0, q=5.0, qmax=6))
    assert d is not None and d.serving_gpus == 2       # cannot grow past 2
    assert d.slots == 4 and "probe slots=4" in d.reason
    assert ctrl.serving_slots == 4
    # the probe was never applied: the next epoch's telemetry still shows
    # 2-slot engines, and the ladder state follows the OBSERVED width
    # instead of mis-keying the table under a width that never ran
    ctrl.observe_serving(_load(backlog=0, occ=0.5))
    assert ctrl.serving_slots == 2
    assert set(ctrl._serving_table) == {(1, 2)}


def test_slot_probe_skips_measured_rungs_and_suppresses_explore():
    """The ladder walk jumps over already-measured rungs (a measured
    neighbor must not stall exploration), and a just-decided probe is not
    overwritten by the exploitation pass in the same decision."""
    ctrl = OnlineGMIController(num_gpu=3, serving_gpus=2, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    ctrl.observe_serving(_load(slots=4, tokens=100, occ=0.5))   # (1, 2)
    ctrl.observe_serving(_load(slots=8, tokens=500, occ=0.5))   # (1, 4)
    assert set(ctrl._serving_table) == {(1, 2), (1, 4)}
    d = ctrl.observe_serving(_load(slots=4, backlog=3, occ=1.0,
                                   q=5.0, qmax=6))
    assert d is not None
    assert d.slots == 8 and "probe slots=8" in d.reason   # 4 is measured
    assert "measured serving optimum" not in d.reason     # probe stands


def test_maybe_replan_matches_controller_instance_count():
    """The router's engine count follows serving_gpus * gmi_per_gpu — the
    same instance count the controller divides telemetry by."""
    router = RequestRouter(engine_factory=make_engine, num_engines=2)
    ctrl = OnlineGMIController(num_gpu=4, serving_gpus=1, gmi_per_gpu=2,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    rng = np.random.default_rng(10)
    for _ in range(4):
        for _ in range(4):
            for _ in range(6):
                router.submit(req(rng, gen=6))
            router.step()
        if router.maybe_replan(ctrl):
            break
    assert ctrl.serving_gpus == 2
    assert router.num_engines == 4          # 2 GPUs x 2 GMIs each
    router.drain()


def test_transient_backlog_is_not_pressure():
    ctrl = OnlineGMIController(num_gpu=4, serving_gpus=1, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=2))
    assert ctrl.observe_serving(_load(backlog=2, occ=1.0)) is None
    # second round of the epoch is clean -> no sustained pressure
    assert ctrl.observe_serving(_load(backlog=0, occ=0.6)) is None
    assert ctrl.serving_gpus == 1


def test_idle_never_drops_last_serving_gpu():
    ctrl = OnlineGMIController(num_gpu=4, serving_gpus=1, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    assert ctrl.observe_serving(_load(occ=0.0, tokens=0, dt=0.0)) is None
    assert ctrl.serving_gpus == 1


def test_serving_explore_adopts_measured_optimum():
    """The measured serving table feeds the same Algorithm-2 explore: a
    slot config measured 5x faster is adopted under min_gain.  Table keys
    come from the OBSERVED telemetry (total slots / instances), and the
    search never moves gmi_per_gpu — that knob belongs to the rollout
    loop."""
    ctrl = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=1,
                               num_env=64,
                               cfg=ControllerConfig(epoch_rounds=1))
    ctrl.observe_serving(_load(slots=4, tokens=100, occ=0.5))
    assert ctrl.serving_slots == 2               # 4 total / 2 instances
    ctrl.observe_serving(_load(slots=16, tokens=500, occ=0.5))
    assert ctrl.serving_slots == 8               # a resize actually ran
    d = ctrl.observe_serving(_load(slots=4, tokens=100, occ=0.5))
    assert d is not None and d.slots == 8
    assert "measured serving optimum (slots=8)" in d.reason
    assert d.gmi_per_gpu == 1 and ctrl.gmi_per_gpu == 1
    assert ctrl.serving_slots == 8
