"""Per-kernel validation: sweep shapes/dtypes in interpret mode and
assert_allclose against the pure-jnp oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _qkv(B, Sq, Skv, H, KH, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KH, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 4, 4, 32),     # MHA
    (2, 128, 128, 8, 2, 64),     # GQA 4:1
    (1, 64, 192, 4, 2, 32),      # cross lengths
    (1, 100, 100, 2, 2, 16),     # ragged (non-multiple of block)
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_shapes_dtypes(shape, dtype):
    B, Sq, Skv, H, KH, hd = shape
    dt = jnp.dtype(dtype)
    q, k, v = _qkv(B, Sq, Skv, H, KH, hd, dt)
    out = ops.attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dt


@pytest.mark.parametrize("window,softcap,causal", [
    (32, None, True), (None, 25.0, True), (48, 30.0, True),
    (None, None, False),
])
def test_flash_attention_variants(window, softcap, causal):
    q, k, v = _qkv(2, 128, 128, 4, 2, 32, jnp.float32)
    out = ops.attention(q, k, v, causal=causal, window=window,
                        softcap=softcap, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dims", [
    (60, 256, 128, 64),          # Ant trunk
    (211, 512, 512, 512, 256),   # ShadowHand trunk
    (24, 256, 128, 64),          # BallBalance trunk
])
@pytest.mark.parametrize("n", [64, 300])
def test_fused_policy_mlp(dims, n):
    ks = jax.random.split(KEY, len(dims))
    ws = [jax.random.normal(ks[i], (dims[i], dims[i + 1])) * 0.05
          for i in range(len(dims) - 1)]
    bs = [jnp.zeros((d,)) for d in dims[1:]]
    x = jax.random.normal(KEY, (n, dims[0]))
    out = ops.policy_mlp(x, ws, bs, block_n=128)
    want = ref.policy_mlp_ref(x, ws, bs)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 2, 128, 16), (2, 4, 256, 32)])
@pytest.mark.parametrize("chunk", [32, 64])
def test_mlstm_kernel(shape, chunk):
    B, H, S, dh = shape
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, H, S, dh))
    v = jax.random.normal(ks[2], (B, H, S, dh))
    li = jax.random.normal(ks[3], (B, H, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)
    out = ops.mlstm(q, k, v, li, lf, chunk=chunk)
    want = ref.mlstm_chunkwise_ref(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("shape", [(8, 4), (12, 5), (32, 64), (7, 128)])
@pytest.mark.parametrize("gamma,lam", [(0.99, 0.95), (1.0, 1.0),
                                       (0.9, 0.5)])
def test_gae_scan_kernel(shape, gamma, lam):
    T, N = shape
    ks = jax.random.split(KEY, 4)
    rewards = jax.random.normal(ks[0], (T, N))
    values = jax.random.normal(ks[1], (T, N))
    dones = (jax.random.uniform(ks[2], (T, N)) < 0.2).astype(jnp.float32)
    last = jax.random.normal(ks[3], (N,))
    advs, rets = ops.gae_norm(rewards, values, dones, last,
                              gamma=gamma, lam=lam)
    want_a, want_r = ref.gae_norm_ref(rewards, values, dones, last,
                                      gamma, lam)
    np.testing.assert_allclose(np.asarray(advs), np.asarray(want_a),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rets), np.asarray(want_r),
                               rtol=1e-5, atol=1e-5)


def test_gae_scan_kernel_matches_unfused_gae():
    """Kernel returns == unfused rollout.gae returns; kernel advs == the
    unfused advs after global normalization."""
    from repro.rl.rollout import gae
    T, N = 16, 12
    ks = jax.random.split(KEY, 4)
    rewards = jax.random.normal(ks[0], (T, N))
    values = jax.random.normal(ks[1], (T, N))
    dones = (jax.random.uniform(ks[2], (T, N)) < 0.1).astype(jnp.float32)
    last = jax.random.normal(ks[3], (N,))
    advs_k, rets_k = ops.gae_norm(rewards, values, dones, last)
    advs_u, rets_u = gae(rewards, values, dones, last)
    np.testing.assert_allclose(np.asarray(rets_k), np.asarray(rets_u),
                               rtol=1e-5, atol=1e-5)
    want = (advs_u - advs_u.mean()) / (advs_u.std() + 1e-8)
    np.testing.assert_allclose(np.asarray(advs_k), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 4), (12, 5), (32, 64), (7, 128)])
@pytest.mark.parametrize("gamma", [0.99, 1.0, 0.5])
def test_nstep_scan_kernel(shape, gamma):
    T, N = shape
    ks = jax.random.split(KEY, 3)
    rewards = jax.random.normal(ks[0], (T, N))
    dones = (jax.random.uniform(ks[1], (T, N)) < 0.2).astype(jnp.float32)
    boot = jax.random.normal(ks[2], (N,))
    rets = ops.nstep_returns(rewards, dones, boot, gamma=gamma)
    want = ref.nstep_returns_ref(rewards, dones, boot, gamma)
    np.testing.assert_allclose(np.asarray(rets), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_nstep_scan_kernel_matches_unfused_a3c_path():
    """The fused kernel must agree with rl.a3c.nstep_returns (the unfused
    lax.scan the trainer uses when use_fused_kernels=False)."""
    from repro.rl.a3c import nstep_returns
    T, N = 16, 12
    ks = jax.random.split(KEY, 3)
    rewards = jax.random.normal(ks[0], (T, N))
    dones = (jax.random.uniform(ks[1], (T, N)) < 0.1).astype(jnp.float32)
    boot = jax.random.normal(ks[2], (N,))
    fused = nstep_returns(rewards, dones, boot, use_fused_kernels=True)
    unfused = nstep_returns(rewards, dones, boot)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("slots,pushes", [(1, 1), (3, 3), (2, 5)])
def test_channel_pack_kernel(slots, pushes):
    """Pallas pack == .at[] oracle across slot writes incl. wraparound."""
    from repro.kernels.channel_pack import (CHANNELS, alloc_rings,
                                            pack_channels)
    T, N, D, A = 6, 4, 5, 2

    def payload(i):
        k = jax.random.fold_in(KEY, i)
        return {"obs": jax.random.normal(k, (T, N, D)),
                "actions": jax.random.normal(k, (T, N, A)),
                "rewards": jax.random.normal(k, (T, N)),
                "dones": jnp.zeros((T, N)),
                "bootstrap": jnp.full((N,), float(i)),
                "actor_version": jnp.int32(i)}

    bufs_k = alloc_rings(payload(0), slots)
    bufs_r = dict(bufs_k)
    for i in range(pushes):
        slot = i % slots
        bufs_k = pack_channels(bufs_k, payload(i), jnp.int32(slot),
                               interpret=True)
        bufs_r = ref.pack_channels_ref(bufs_r, payload(i), slot)
    for c in CHANNELS:
        np.testing.assert_array_equal(np.asarray(bufs_k[c]),
                                      np.asarray(bufs_r[c]))


def test_mlstm_kernel_matches_model_block_math():
    """The kernel must agree with the model-level recurrent decode path."""
    from repro.models import ssm
    B, H, S, dh = 1, 2, 64, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, H, S, dh))
    v = jax.random.normal(ks[2], (B, H, S, dh))
    li = jax.random.normal(ks[3], (B, H, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)
    out = ops.mlstm(q, k, v, li, lf, chunk=16)
    # step the exact recurrence
    C = jnp.zeros((B, H, dh, dh))
    n = jnp.zeros((B, H, dh))
    m = jnp.zeros((B, H))
    scale = dh ** -0.5
    outs = []
    for t in range(S):
        m_new = jnp.maximum(lf[..., t] + m, li[..., t])
        i_s = jnp.exp(li[..., t] - m_new)
        f_s = jnp.exp(lf[..., t] + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", v[:, :, t], k[:, :, t])
        n = f_s[..., None] * n + i_s[..., None] * k[:, :, t]
        qt = q[:, :, t] * scale
        num = jnp.einsum("bhe,bhde->bhd", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        outs.append(num / den[..., None])
        m = m_new
    want = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


# ------------------------------------------------------------ paged decode --
def _paged_case(B, M, page, H, KH, hd, dtype, seed=0):
    """A random but consistent paged pool: each batch row decodes at a
    random absolute position, owning shuffled physical pages for every
    virtual page at or below it (page 0 is the shared trash page)."""
    rng = np.random.default_rng(seed)
    N = B * M + 1
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k_pages = jax.random.normal(ks[1], (N, page, KH, hd), dtype)
    v_pages = jax.random.normal(ks[2], (N, page, KH, hd), dtype)
    slot_pos = np.full((N, page), -1, np.int32)
    table = np.full((B, M), -1, np.int32)
    positions = np.zeros((B,), np.int32)
    perm = iter(rng.permutation(np.arange(1, N)))
    for b in range(B):
        pos = int(rng.integers(1, M * page))
        positions[b] = pos
        for vp in range(pos // page + 1):
            pid = int(next(perm))
            table[b, vp] = pid
            hi = min(page, pos + 1 - vp * page)
            slot_pos[pid, :hi] = vp * page + np.arange(hi)
    return (q, k_pages, v_pages, jnp.asarray(slot_pos),
            jnp.asarray(table), jnp.asarray(positions))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("window", [None, 8])
def test_paged_attention_kernel(dtype, window):
    """Pallas gather-decode through the page table == dense ref oracle,
    full-depth and sliding-window, f32 and bf16."""
    dt = jnp.dtype(dtype)
    q, kp, vp, sp, table, pos = _paged_case(3, 4, 8, 4, 2, 32, dt, seed=5)
    out = ops.paged_attention(q, kp, vp, sp, table, pos, window=window,
                              interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, sp, table, pos, window=window)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dt


def test_paged_attention_kernel_gqa_softcap():
    """GQA 4:1 heads with logit softcap, scattered unmapped pages."""
    q, kp, vp, sp, table, pos = _paged_case(2, 5, 8, 8, 2, 16,
                                            jnp.float32, seed=9)
    out = ops.paged_attention(q, kp, vp, sp, table, pos, softcap=20.0,
                              interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, sp, table, pos, softcap=20.0)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
