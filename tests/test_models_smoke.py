"""Deliverable (f): per-architecture smoke tests — reduced variants of the
same family run one forward + one train step on CPU, asserting output
shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.configs.base import InputShape
from repro.data import make_batch
from repro.models import transformer as T
from repro.optim import adam_init, adam_update

SMOKE = InputShape("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    assert (cfg.num_super * len(cfg.block_pattern) if cfg.block_pattern
            else cfg.num_layers) <= 2
    key = jax.random.key(0)
    params = T.init_model(key, cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMOKE).items()}

    logits, aux = T.forward(params, cfg, batch)
    B = SMOKE.global_batch
    S = logits.shape[1]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN logits"

    opt = adam_init(params)
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch, remat=False))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    new_params, opt = adam_update(grads, opt, params, lr=1e-3, grad_clip=1.0)
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN params"
    # the step actually changed something
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }[arch]
    L = cfg.num_super * len(cfg.block_pattern) if cfg.block_pattern \
        else cfg.num_layers
    assert (L, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff,
            cfg.vocab_size) == expected
    assert cfg.source, "every config must cite its source"


def test_moe_configs_expert_counts():
    assert get_config("mixtral-8x7b").num_experts == 8
    assert get_config("mixtral-8x7b").experts_per_token == 2
    assert get_config("granite-moe-1b-a400m").num_experts == 32
    assert get_config("granite-moe-1b-a400m").experts_per_token == 8
    assert get_config("zamba2-7b").ssm_state_dim == 64
